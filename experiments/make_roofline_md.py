"""Regenerate the EXPERIMENTS.md roofline table from dry-run JSONs."""

import glob
import json

import sys
sys.path.insert(0, "src")


def useful_ratio(arch_id, shape_name, kind, rf):
    from repro.configs.registry import get_arch
    from repro.launch.roofline import model_flops_lm
    arch = get_arch(arch_id)
    if arch.family != "lm" or rf["flops_per_chip"] == 0:
        return None
    shape = arch.shapes[shape_name]
    if kind == "train":
        n_tok = shape.dim("global_batch") * shape.dim("seq_len")
        # with layer remat the compiled program re-runs the forward:
        # ideal = 8*N*D (2 fwd + 4 bwd + 2 remat-fwd)
        mf = model_flops_lm(arch.model, n_tok, train=True) * 8.0 / 6.0
    elif shape.kind == "prefill":
        n_tok = shape.dim("global_batch") * shape.dim("seq_len")
        mf = model_flops_lm(arch.model, n_tok, train=False)
    elif shape.kind == "decode":
        mf = model_flops_lm(arch.model, shape.dim("global_batch"),
                            train=False)
    else:
        return None
    return (mf / rf["chips"]) / rf["flops_per_chip"]


def main(out_path="experiments/roofline_table.md"):
    rows = []
    for path in sorted(glob.glob("experiments/dryrun/*/*.json")):
        with open(path) as f:
            r = json.load(f)
        if r["status"] != "ok":
            rows.append((r["mesh"], r["arch"], r["shape"], "FAILED",
                         r.get("error", "")))
            continue
        rf = r["roofline"]
        ur = useful_ratio(r["arch"], r["shape"], r.get("kind"), rf)
        from repro.configs.registry import get_arch
        spec = get_arch(r["arch"])
        has_scans = (spec.family == "lm"
                     or (getattr(spec.model, "kind", "") == "equiformer_v2"
                         and r["shape"] == "ogb_products"))
        if "cost_variant" in r and "error" not in r["cost_variant"]:
            counting = "unrolled (exact)"
        elif has_scans:
            counting = "scan-body-once (×L under-count)"
        else:
            counting = "exact (no scans)"
        rows.append((
            r["mesh"], r["arch"], r["shape"], rf["bound"],
            rf["compute_s"] * 1e3, rf["memory_s"] * 1e3,
            rf["collective_s"] * 1e3,
            r["collectives"]["total_count"],
            r.get("memory", {}).get("temp_bytes", 0) / 1e9,
            ur, counting))
    with open(out_path, "w") as f:
        f.write("| mesh | arch | shape | bound | compute ms | memory ms | "
                "collective ms | #coll | temp GB/chip | useful-compute | "
                "counting |\n")
        f.write("|---|---|---|---|---|---|---|---|---|---|---|\n")
        for r in rows:
            if r[3] == "FAILED":
                f.write(f"| {r[0]} | {r[1]} | {r[2]} | FAILED | | | | | | "
                        "| |\n")
                continue
            ur = f"{r[9]:.3f}" if r[9] else "—"
            f.write(f"| {r[0]} | {r[1]} | {r[2]} | {r[3]} | {r[4]:.2f} | "
                    f"{r[5]:.2f} | {r[6]:.2f} | {r[7]} | {r[8]:.2f} | "
                    f"{ur} | {r[10]} |\n")
    print(f"wrote {out_path} ({len(rows)} rows)")


if __name__ == "__main__":
    main(*sys.argv[1:])
