import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Hillclimb cell A: jedinet-50p x stream_1k (paper-representative).

Baseline (paper-faithful strength-reduced path, batch 1000, fp32):
memory-bound.  Iterations per EXPERIMENTS.md §Perf:

  v0 baseline        forward_sr,      batch 1000, fp32
  v1 pad-batch       forward_sr,      batch 1024 (shards 16-way), fp32
  v2 bf16            forward_sr,      batch 1024, bf16 compute
  v3 bilinear-split  forward_sr_split(grid), 1024, bf16  (B never built)
  v4 no-grid gather  forward_sr_split(gather) for comparison

    PYTHONPATH=src python experiments/hillclimb_jedi.py
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def run_variant(name, forward, batch, dtype):
    from repro.core import interaction_net as inet
    from repro.launch import roofline
    from repro.launch.mesh import make_production_mesh
    from repro.parallel.sharding import axis_rules, batch_shardings

    mesh = make_production_mesh(multi_pod=False)
    cfg = inet.JediNetConfig(n_objects=50, n_features=16,
                             fr_hidden=(50, 50, 50), fo_hidden=(50, 50, 50),
                             phi_hidden=(50, 50, 50), compute_dtype=dtype)
    a_params = jax.eval_shape(lambda k: inet.init(k, cfg),
                              jax.random.PRNGKey(0))
    a_x = jax.ShapeDtypeStruct((batch, 50, 16), jnp.float32)
    p_sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), a_params,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    x_sh = batch_shardings({"x": a_x}, mesh,
                           {"x": ("batch", None, None)})["x"]

    def step(params, x):
        return forward(params, cfg, x)

    with mesh, axis_rules(mesh):
        compiled = jax.jit(step, in_shardings=(p_sh, x_sh)) \
            .lower(a_params, a_x).compile()
    rec = roofline.from_compiled(compiled, mesh)
    r = rec["roofline"]
    print(f"{name:<18} sharded={tuple(x_sh.spec)!s:<22} "
          f"bound={r['bound']:<10} c={r['compute_s']*1e6:9.1f}us "
          f"m={r['memory_s']*1e6:9.1f}us x={r['collective_s']*1e6:7.1f}us "
          f"per-jet-HBM={r['hbm_bytes_per_chip']/batch*256:,.0f}B*chips/jet")
    return rec


def main():
    from repro.core import interaction_net as inet
    out = {}
    out["v0_baseline"] = run_variant(
        "v0 baseline", inet.forward_sr, 1000, "float32")
    out["v1_pad_batch"] = run_variant(
        "v1 pad-batch", inet.forward_sr, 1024, "float32")
    out["v2_bf16"] = run_variant(
        "v2 bf16", inet.forward_sr, 1024, "bfloat16")
    out["v3_split_grid"] = run_variant(
        "v3 bilinear-grid",
        lambda p, c, x: inet.forward_sr_split(p, c, x, grid=True),
        1024, "bfloat16")
    out["v4_split_gather"] = run_variant(
        "v4 bilinear-gather",
        lambda p, c, x: inet.forward_sr_split(p, c, x, grid=False),
        1024, "bfloat16")
    os.makedirs("experiments/perf", exist_ok=True)
    with open("experiments/perf/jedinet50_stream.json", "w") as f:
        json.dump(out, f, indent=1, default=str)


if __name__ == "__main__":
    main()
