"""Roofline-term derivation from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs      / (chips * 197e12  bf16 FLOP/s)
    memory     = HLO_bytes      / (chips * 819e9   B/s HBM)
    collective = collective_B   / (chips * 50e9    B/s per ICI link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the optimized HLO text and sum the
output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.  cost_analysis reports
PER-DEVICE numbers for SPMD-partitioned modules (the module is the
per-device program), so terms divide by chips only where the metric is
whole-job (see below: we treat cost_analysis as per-chip already and do
NOT divide again; collective bytes are summed per-device the same way).
"""

from __future__ import annotations

import dataclasses
import re

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (effective, one direction)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")

# matches e.g.  f32[128,1024]{1,0}  or  bf16[4]  or tuple elements
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*("
    + "|".join(_COLLECTIVE_OPS) + r")(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of collective ops in optimized HLO, by op kind.

    ``*-start`` ops carry the payload; matching ``*-done`` ops repeat the
    shape, so -done lines are skipped to avoid double counting.
    """
    per_op = {k: 0 for k in _COLLECTIVE_OPS}
    count = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        for op in _COLLECTIVE_OPS:
            # opcode appears between the '=' shape and '(' operands
            if re.search(r"\b" + re.escape(op) + r"(-start)?\(", rhs):
                if re.search(r"\b" + re.escape(op) + r"-done\(", rhs):
                    break
                # bytes = output shape(s) of the instruction
                shape_part = rhs.split(op)[0]
                per_op[op] += _shape_bytes(shape_part)
                count[op] += 1
                break
    return {"bytes_by_op": per_op,
            "counts_by_op": count,
            "total_bytes": sum(per_op.values()),
            "total_count": sum(count.values())}


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per device
    hbm_bytes: float             # per device
    coll_bytes: float            # per device
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """No-overlap upper bound on the step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "step_s": self.step_s,
        }


def from_compiled(compiled, mesh) -> dict:
    """Derive roofline terms + memory stats from a compiled executable."""
    chips = mesh.devices.size
    ca = compiled.cost_analysis()
    if isinstance(ca, list):         # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    terms = RooflineTerms(flops=flops, hbm_bytes=hbm,
                          coll_bytes=float(coll["total_bytes"]), chips=chips)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or 0),
        }
    except Exception:
        pass
    return {"roofline": terms.to_dict(), "collectives": coll, "memory": mem}


def model_flops_lm(cfg, n_tokens: int, train: bool = True) -> float:
    """MODEL_FLOPS = 6*N_active*D for train, 2*N*D for inference."""
    import jax
    import jax.numpy as jnp  # noqa: F401
    from repro.models import transformer as tfm

    a_params = jax.eval_shape(
        lambda k: tfm.init(k, cfg), jax.random.PRNGKey(0))
    total = sum(int(np_prod(l.shape))
                for l in jax.tree_util.tree_leaves(a_params))
    if cfg.moe is not None:
        # active experts per token = top_k of n_experts (+ dense residual)
        moe = cfg.moe
        expert_p = 3 * cfg.d_model * cfg.d_ff
        per_layer_moe = moe.n_experts * expert_p
        active_moe = moe.top_k * expert_p
        total_active = total - cfg.n_layers * (per_layer_moe - active_moe)
    else:
        total_active = total
    return (6.0 if train else 2.0) * total_active * n_tokens


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out
