# NOTE: repro.launch.dryrun must be executed as a fresh process
# (python -m repro.launch.dryrun) so its XLA_FLAGS line runs before jax
# initializes; do not import it from here.
from repro.launch.mesh import make_production_mesh, make_host_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]
