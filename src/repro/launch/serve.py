"""Batched LM serving driver: prefill + decode loop with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --tiny --requests 8 --max-new 16

Demonstrates the serving half of the framework on CPU with a reduced
config (``--tiny`` swaps the arch config for a 2-layer miniature, same
code path): request queue -> bucketed prefill (pads prompts up a
power-of-two length ladder so mixed lengths share one compile) ->
decode loop over the *batched* KV cache with per-request stop handling
and slot recycling (continuous batching).

ALL the behavior lives in :class:`repro.serving.lm.LMEngine` on the
shared serving fabric — this module is the thin shell (argparse + one
call), and ``tests/test_thin_cli.py`` keeps it that way with an AST
guard.  The fabric port preserves the pre-refactor scheduling exactly
(greedy token streams are pinned by ``tests/test_loop.py``) and adds
deadline shedding (``--deadline-ms``), health reporting (``--health``)
and the shared metrics surface for free.
"""

from __future__ import annotations

import argparse

from repro.serving.lm import (  # noqa: F401  (Request/tiny_config re-exported)
    LMRequest as Request,
    build_lm_cli,
    run_lm_cli,
    tiny_config,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    build_lm_cli(ap)
    return run_lm_cli(ap.parse_args(argv))


if __name__ == "__main__":
    main()
