"""Batched serving driver: prefill + decode loop with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --tiny --requests 8 --max-new 16

Demonstrates the serving half of the framework on CPU with a reduced
config (``--tiny`` swaps the arch config for a 2-layer miniature, same
code path):

* request queue -> prefill (builds the KV cache for each request),
* decode loop over the *batched* cache (one token per request per step),
* per-request stop handling with slot recycling (continuous batching):
  finished requests release their cache slot to the next queued request.

The decode step is the exact function the decode_32k / long_500k dry-run
cells lower to the production mesh.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)


def tiny_config(cfg):
    import dataclasses as dc
    return dc.replace(cfg, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                      d_ff=256, vocab_size=512, compute_dtype="float32",
                      remat="none")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_arch
    from repro.models import transformer as tfm

    arch = get_arch(args.arch)
    assert arch.family == "lm", "serve driver is for LM archs"
    cfg = tiny_config(arch.model) if args.tiny else arch.model

    rng = np.random.RandomState(0)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    v = cfg.vocab_size

    queue = [Request(i, rng.randint(0, v, args.prompt_len), args.max_new)
             for i in range(args.requests)]
    done: list = []

    # batched cache over --slots concurrent requests
    cache = tfm.init_cache(cfg, args.slots, args.max_seq)
    slot_req: list = [None] * args.slots

    prefill = jax.jit(lambda p, t: tfm.forward(p, cfg, t, return_cache=True))
    decode = jax.jit(lambda p, c, t: tfm.decode_step(p, cfg, c, t))

    def admit(slot: int, req: Request):
        """Prefill one request and splice its cache into the batch slot."""
        logits, _, pc = prefill(params, jnp.asarray(req.prompt[None]))
        t = cache["k"].shape[2]
        pl = req.prompt.shape[0]
        for key in ("k", "v"):
            upd = jnp.zeros_like(cache[key][:, slot])
            upd = upd.at[:, :pl].set(pc[key][:, 0])
            cache[key] = cache[key].at[:, slot].set(upd)
        sp = jnp.full((t,), -1, jnp.int32).at[:pl].set(jnp.arange(pl))
        cache["slot_pos"] = cache["slot_pos"].at[slot].set(sp)
        cache["pos"] = cache["pos"].at[slot].set(pl)
        first = int(jnp.argmax(logits[0, -1]))
        req.out.append(first)
        slot_req[slot] = req

    t0 = time.time()
    steps = 0
    while queue or any(slot_req):
        # fill free slots (continuous batching)
        for s in range(args.slots):
            if slot_req[s] is None and queue:
                admit(s, queue.pop(0))
        toks = jnp.asarray([
            (slot_req[s].out[-1] if slot_req[s] else 0)
            for s in range(args.slots)], jnp.int32)
        logits, cache = decode(params, cache, toks)
        steps += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in range(args.slots):
            req = slot_req[s]
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            if len(req.out) >= req.max_new:
                done.append(req)
                slot_req[s] = None        # release slot

    dt = time.time() - t0
    print(f"[serve] {len(done)} requests, {steps} decode steps, "
          f"{steps / dt:.1f} steps/s")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
