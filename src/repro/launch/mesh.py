"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 host devices before first jax init; smoke tests see the
real single CPU device.

Mesh shapes (TPU v5e pods):
    single-pod: (16, 16)    axes ("data", "model")   — 256 chips
    multi-pod : (2, 16, 16) axes ("pod", "data", "model") — 512 chips

Axis semantics (bound by repro.parallel.sharding.DEFAULT_RULES):
    pod   — data parallelism across pods (gradient all-reduce over DCI)
    data  — FSDP + expert parallelism + batch DP inside a pod
    model — tensor parallelism / sequence parallelism inside a pod
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1D (data,) mesh — for CPU examples."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
