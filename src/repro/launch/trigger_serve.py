"""Streaming L1-trigger serving CLI over the serving engine.

    PYTHONPATH=src python -m repro.launch.trigger_serve \
        --n-objects 30 --batch 256 --batches 40 --forward fused_full

The LHC L1 trigger is a hard-real-time stream: events arrive at a fixed
rate and every event must be classified within the trigger latency budget
(the paper targets < 1 us per jet on the FPGA).  A TPU trigger tier plays
a different position in the same pipeline: it amortizes weight traffic
over a batch of events, so the serving question becomes *sustained
throughput at bounded tail latency* rather than single-jet latency.

ALL the behavior lives in :mod:`repro.serving.trigger` — this module is
the thin shell (argparse + one call), and ``tests/test_thin_cli.py``
keeps it that way with an AST guard: no batching, engine or scheduling
logic may creep back in here.  ``make_stream`` and ``serve_stream`` are
re-exported for drivers and tests that historically imported them from
this module.

Serving goes through the fault-tolerant
:class:`~repro.serving.resilient.ResilientEngine` — the degradation
ladder, deadline shedding and watchdog are always armed.  ``--health``
prints the health state machine's report after the run; ``--drill
SEAM[:TIMES[:DELAY_S]]`` arms the fault-injection harness
(:mod:`repro.serving.faults`) and serves through the guarded
per-request path (see EXPERIMENTS.md §Fault drills); ``--list-paths``
prints the forward-path registry with each path's fallback chain and
bucket policy.
"""

from __future__ import annotations

import argparse

from repro.serving import serve_stream  # noqa: F401  (re-export: tests/drivers)
from repro.serving.trigger import (  # noqa: F401  (make_stream re-exported)
    build_trigger_cli,
    make_stream,
    run_trigger_cli,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    build_trigger_cli(ap)
    return run_trigger_cli(ap.parse_args(argv))


if __name__ == "__main__":
    main()
