"""Streaming L1-trigger serving CLI over the serving engine.

    PYTHONPATH=src python -m repro.launch.trigger_serve \
        --n-objects 30 --batch 256 --batches 40 --forward fused_full

The LHC L1 trigger is a hard-real-time stream: events arrive at a fixed
rate and every event must be classified within the trigger latency budget
(the paper targets < 1 us per jet on the FPGA).  A TPU trigger tier plays
a different position in the same pipeline: it amortizes weight traffic
over a batch of events, so the serving question becomes *sustained
throughput at bounded tail latency* rather than single-jet latency.

All the machinery lives in :mod:`repro.serving` now — this module is the
thin CLI: build a :class:`~repro.serving.ServingEngine` for the chosen
forward path, pump a synthetic event stream through its double-buffered
device-feed loop (:func:`~repro.serving.serve_stream`, re-exported here),
and print the rolling KGPS / p50 / p99 next to the TPU-model roofline
for the bucket the stream rode in.  ``--batch`` need not match a compile
bucket: the engine pads to the nearest autotuner ladder rung.

On CPU (CI) the pipeline degenerates to a correct but synchronous loop;
the numbers are only meaningful on a real accelerator.  ``--forward``
accepts any registered path (:mod:`repro.core.paths`) — the choices,
the params transform (e.g. int8 quantization) and the roofline level
all come off the path's ``PathSpec``, so a newly registered path is
servable here with zero CLI edits; ``--list-paths`` prints the
registry (including each path's fallback chain and bucket policy).
``fused_full`` is the production path, with ``--interpret`` available
(auto-enabled off-TPU) so the whole driver can be smoke-tested off-TPU.

Serving goes through the fault-tolerant
:class:`~repro.serving.resilient.ResilientEngine` — the degradation
ladder, deadline shedding and watchdog are always armed.  ``--health``
prints the health state machine's report after the run; ``--drill
SEAM[:TIMES[:DELAY_S]]`` arms the fault-injection harness
(:mod:`repro.serving.faults`) against the primary path and pumps the
stream through the guarded per-request path instead of the raw feed
loop, so every degraded-mode transition can be exercised from the
command line (see EXPERIMENTS.md §Fault drills).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import paths
from repro.core.interaction_net import JediNetConfig, init
from repro.data.jets import make_jets
from repro.serving import (  # noqa: F401  (serve_stream re-exported for drivers/tests)
    FaultInjector,
    ResilientEngine,
    percentile,
    serve_stream,
)


def make_stream(rng, n_batches: int, batch: int, n_objects: int,
                n_features: int):
    """Pre-generated synthetic event stream, fully materialized so the
    per-jet numpy generation loop stays OFF the timed serving path — the
    latencies below must measure transfer+compute, not the generator."""
    return [make_jets(rng, batch, n_objects, n_features)[0]
            for _ in range(n_batches)]


def _print_health(engine) -> None:
    """The health state machine's operator view (``--health``)."""
    h = engine.health()
    print(f"[health] state={h['state']} base={h['base_path']} "
          f"chain={'>'.join(h['chain'])} inflight={h['inflight']}")
    for bucket, st in h["buckets"].items():
        probe = ("-" if st["next_probe_in_s"] is None
                 else f"{st['next_probe_in_s']:.2f}s")
        print(f"  bucket {bucket:>5}: path={st['path']} level={st['level']} "
              f"demotions={st['demotions']} next_probe_in={probe}"
              f"{' DOWN' if st['down'] else ''}")
    if h["counters"]:
        print("  counters: " + " ".join(f"{k}={v}"
                                        for k, v in h["counters"].items()))
    else:
        print("  counters: (none)")


def _parse_drills(specs, injector, path):
    """Arm ``SEAM[:TIMES[:DELAY_S]]`` drill specs against ``path``."""
    for spec in specs:
        parts = spec.split(":")
        times = float(parts[1]) if len(parts) > 1 else 1.0
        delay = float(parts[2]) if len(parts) > 2 else 0.05
        injector.arm(parts[0], path=path, times=times, delay_s=delay)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-objects", type=int, default=30)
    ap.add_argument("--n-features", type=int, default=16)
    ap.add_argument("--batch", type=int, default=256,
                    help="events per stream tick (the trigger's time slice)")
    ap.add_argument("--batches", type=int, default=40)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--forward", default="fused_full",
                    choices=paths.available())
    ap.add_argument("--compute-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--interpret", action="store_true",
                    help="force Pallas interpret mode (auto-enabled off-TPU)")
    ap.add_argument("--list-paths", action="store_true",
                    help="print the forward-path registry and exit")
    ap.add_argument("--health", action="store_true",
                    help="print the engine health report after the run")
    ap.add_argument("--drill", action="append", default=None,
                    metavar="SEAM[:TIMES[:DELAY_S]]",
                    help="arm a fault against the primary path (repeatable; "
                         "seams: compile, dispatch, input_nan, output_nan, "
                         "latency, stuck) and serve through the guarded "
                         "per-request path")
    ap.add_argument("--watchdog-s", type=float, default=30.0,
                    help="stuck-dispatch watchdog budget")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-tick serve deadline (drill path); expired "
                         "ticks are shed, not dispatched")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.list_paths:
        # Registry table PLUS each path's resolved bucket policy (per-
        # sample VMEM model, weight residency, the ladder it earns) for
        # this CLI's config — the operator-facing answer to "why does
        # the quantized path get deeper buckets than fp32?".
        cfg = JediNetConfig(n_objects=args.n_objects,
                            n_features=args.n_features,
                            compute_dtype=args.compute_dtype)
        params = init(jax.random.PRNGKey(args.seed), cfg)
        print(paths.describe(cfg=cfg, params=params,
                             max_batch=max(args.batch, 1)))
        return

    cfg = JediNetConfig(n_objects=args.n_objects, n_features=args.n_features,
                        compute_dtype=args.compute_dtype)
    params = init(jax.random.PRNGKey(args.seed), cfg)
    injector = None
    if args.drill:
        injector = FaultInjector()
        _parse_drills(args.drill, injector, args.forward)
    engine = ResilientEngine(params, cfg, forward=args.forward,
                             interpret=args.interpret or None,
                             max_batch=max(args.batch, 1),
                             injector=injector,
                             watchdog_s=args.watchdog_s)

    rng = np.random.RandomState(args.seed)
    stream = make_stream(rng, args.batches, args.batch, args.n_objects,
                         args.n_features)

    if args.drill:
        # guarded per-request path: every batch rides the full ladder —
        # NaN detection, watchdog, shedding — so injected faults are
        # absorbed, counted, and visible in --health, never raised.
        served = shed = 0
        t0 = time.perf_counter()
        for tick in stream:
            deadline = (None if args.deadline_ms is None
                        else engine._clock() + args.deadline_ms * 1e-3)
            out = engine.infer(tick, deadline=deadline)
            if out is None:
                shed += 1
            else:
                served += 1
        wall = time.perf_counter() - t0
        snap = engine.metrics.snapshot()
        print(f"[trigger_serve] DRILL forward={args.forward} "
              f"faults={','.join(args.drill)} ticks={args.batches} "
              f"served={served} shed={shed} wall={wall:.3f}s")
        print(f"  latency    p50 {snap['p50_us']:8.1f} us   "
              f"p99 {snap['p99_us']:8.1f} us  per batch")
        _print_health(engine)
        return

    res = engine.run_stream(stream, warmup=args.warmup)

    if not res["latencies"]:
        print("[trigger_serve] stream too short for stats "
              f"(need > warmup={args.warmup} batches, got {args.batches})")
        if args.health:
            _print_health(engine)
        return

    snap = engine.metrics.snapshot()
    bucket = res["bucket"]
    model = engine.roofline([bucket])[bucket]

    print(f"[trigger_serve] forward={args.forward} "
          f"n_objects={args.n_objects} batch={args.batch} bucket={bucket} "
          f"dtype={args.compute_dtype} shards={engine.n_shards}")
    print(f"  sustained  {snap['kgps']:8.1f} KGPS  "
          f"({res['events']} events / {res['wall_s']:.3f} s)")
    print(f"  latency    p50 {snap['p50_us']:8.1f} us   "
          f"p99 {snap['p99_us']:8.1f} us  per batch")
    print(f"  per-event  p50 {snap['per_event_p50_us']:8.3f} us")
    print(f"  roofline   modeled {model['step_us']:.1f} us/step "
          f"({model['bound']}-bound, {model['hbm_bytes'] / 1e6:.2f} MB HBM, "
          f"level={model['fused_level']})")
    print(f"  serving    path={engine.active_path(bucket)} "
          f"(chain {'>'.join(engine.chain)})")
    if args.health:
        _print_health(engine)


if __name__ == "__main__":
    main()
