"""Streaming L1-trigger serving driver for the fused JEDI-net paths.

    PYTHONPATH=src python -m repro.launch.trigger_serve \
        --n-objects 30 --batch 256 --batches 40 --forward sr_split

The LHC L1 trigger is a hard-real-time stream: events arrive at a fixed
rate and every event must be classified within the trigger latency budget
(the paper targets < 1 us per jet on the FPGA).  A TPU trigger tier plays
a different position in the same pipeline: it amortizes weight traffic
over a batch of events, so the serving question becomes *sustained
throughput at bounded tail latency* rather than single-jet latency.

This driver pumps a synthetic event stream through a jitted forward path
with a software pipeline that mirrors the paper's ping-pong-buffer
architecture at the host<->device boundary:

* double-buffered host->device transfer — batch k+1 is `device_put` (an
  async enqueue on TPU) while batch k is still computing, so PCIe/ICI
  transfer hides behind compute exactly like the FPGA's coarse-grained
  pipeline overlaps stages;
* async dispatch — the jitted call returns a future; we only block on
  batch k when batch k+1 is already in flight;
* per-batch latency is measured enqueue->ready and reported as p50/p99
  alongside sustained KGPS (thousand graphs = events per second).

On CPU (CI) this degenerates to a correct but synchronous pipeline; the
numbers are only meaningful on a real accelerator.  ``--forward`` accepts
any FORWARD_FNS key; ``fused_full`` is the production path (one Pallas
kernel, HBM traffic = weights + x in, logits out), with ``--interpret``
available so the whole driver can be smoke-tested off-TPU.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codesign
from repro.core.interaction_net import FORWARD_FNS, JediNetConfig, init
from repro.data.jets import make_jets


def percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q))


def make_stream(rng, n_batches: int, batch: int, n_objects: int,
                n_features: int):
    """Pre-generated synthetic event stream, fully materialized so the
    per-jet numpy generation loop stays OFF the timed serving path — the
    latencies below must measure transfer+compute, not the generator."""
    return [make_jets(rng, batch, n_objects, n_features)[0]
            for _ in range(n_batches)]


def serve_stream(fwd, stream, *, warmup: int = 2):
    """Run the double-buffered serving loop; returns per-batch latencies.

    ``fwd`` must be an async-dispatch callable (jitted) taking a device
    array; latencies are seconds from host handoff to logits-ready.
    """
    latencies = []
    events = 0
    it = iter(stream)

    # prime the pipeline: first transfer issued before the loop body
    try:
        nxt = jax.device_put(next(it))
    except StopIteration:
        return latencies, events, 0.0

    t_start = None
    k = 0
    while nxt is not None:
        cur = nxt
        t0 = time.perf_counter()
        out = fwd(cur)                      # async dispatch
        try:
            nxt = jax.device_put(next(it))  # overlap next H2D with compute
        except StopIteration:
            nxt = None
        out.block_until_ready()
        t1 = time.perf_counter()
        k += 1
        if k <= warmup:                     # exclude compile from stats
            t_start = time.perf_counter()
            continue
        latencies.append(t1 - t0)
        events += cur.shape[0]
    wall = (time.perf_counter() - t_start) if t_start else 0.0
    return latencies, events, wall


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-objects", type=int, default=30)
    ap.add_argument("--n-features", type=int, default=16)
    ap.add_argument("--batch", type=int, default=256,
                    help="events per device batch (the trigger's time slice)")
    ap.add_argument("--batches", type=int, default=40)
    ap.add_argument("--forward", default="fused_full",
                    choices=sorted(FORWARD_FNS))
    ap.add_argument("--compute-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--interpret", action="store_true",
                    help="force Pallas interpret mode (auto-enabled off-TPU)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = JediNetConfig(n_objects=args.n_objects, n_features=args.n_features,
                        compute_dtype=args.compute_dtype)
    params = init(jax.random.PRNGKey(args.seed), cfg)

    fn = FORWARD_FNS[args.forward]
    if args.forward in ("fused", "fused_full"):
        # compiled Pallas needs a real TPU; fall back to interpret elsewhere
        interpret = args.interpret or jax.default_backend() != "tpu"
        fn = functools.partial(fn, interpret=interpret)
    fwd = jax.jit(lambda x: fn(params, cfg, x))

    rng = np.random.RandomState(args.seed)
    stream = make_stream(rng, args.batches, args.batch, args.n_objects,
                         args.n_features)
    lat, events, wall = serve_stream(fwd, stream)

    if not lat:
        print("[trigger_serve] stream too short for stats "
              f"(need > warmup batches, got {args.batches})")
        return

    kgps = events / wall / 1e3 if wall > 0 else float("nan")
    p50, p99 = percentile(lat, 50) * 1e6, percentile(lat, 99) * 1e6
    # roofline context: what the TPUModel says this path's step should cost
    level = {"fused_full": "full", "fused": "edge"}.get(args.forward, "none")
    model = codesign.TPUModel.evaluate(
        codesign.TPUDesignPoint(cfg=cfg, batch=args.batch), fused=level)

    print(f"[trigger_serve] forward={args.forward} "
          f"n_objects={args.n_objects} batch={args.batch} "
          f"dtype={args.compute_dtype}")
    print(f"  sustained  {kgps:8.1f} KGPS  ({events} events / {wall:.3f} s)")
    print(f"  latency    p50 {p50:8.1f} us   p99 {p99:8.1f} us  per batch")
    print(f"  per-event  p50 {p50 / args.batch:8.3f} us")
    print(f"  roofline   modeled {model['step_us']:.1f} us/step "
          f"({model['bound']}-bound, "
          f"{model['hbm_bytes'] / 1e6:.2f} MB HBM, level={level})")


if __name__ == "__main__":
    main()
