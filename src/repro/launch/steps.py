"""Per-(architecture x shape) step functions + abstract input specs.

Every dry-run cell resolves to a ``CellProgram``:

    step_fn      : pure function to jit (train_step or serve_step)
    make_abstract: () -> (args tuple of ShapeDtypeStruct pytrees,
                          in_shardings tuple, out_shardings)
    describe     : metadata for the roofline report

LM ``decode_*`` / ``long_*`` cells lower ``serve_step`` (one token against a
KV cache); ``prefill_*`` lowers a full-sequence forward returning last-token
logits + the built cache; ``train_*`` lowers loss+grad+optimizer-update.
GNN cells lower family-specific train steps; recsys cells lower train /
bulk-score / retrieval programs.  Encoder-only archs have no decode cells in
the assignment, so no special-casing is needed.

The optimizer for LM train cells is Adafactor (AdamW's fp32 moments for
arctic-480b would need ~3.8 TB — see configs/arctic_480b.py); GNN/recsys/
JEDI train cells use AdamW.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec
from repro.core import interaction_net as inet
from repro.models import recsys as fm_lib
from repro.models import transformer as tfm
from repro.models.gnn import GNN_MODULES
from repro.models.gnn import segment_ops as seg
from repro.parallel import sharding as shd
from repro.training import make_optimizer, make_train_step
from repro.training.schedule import warmup_cosine
from repro.data.neighbor_sampler import static_budget


@dataclasses.dataclass
class CellProgram:
    arch_id: str
    shape_name: str
    kind: str                    # train | serve
    step_fn: Callable
    make_abstract: Callable      # () -> (args, in_shardings, out_shardings)
    notes: str = ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def pad512(n: int) -> int:
    """Round node/edge/candidate counts up to a 512 multiple so the flat
    set axes shard over the full 512-chip mesh.  The data pipeline pads
    with inert elements (features 0, edges into a sink node, labels -1)."""
    return -(-int(n) // 512) * 512


def _abstract_like(tree):
    return jax.tree_util.tree_map(
        lambda l: sds(l.shape, l.dtype), tree)


def _replicated(mesh, tree):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ===========================================================================
# LM family
# ===========================================================================

def _lm_abstract_params(cfg):
    return jax.eval_shape(lambda k: tfm.init(k, cfg), jax.random.PRNGKey(0))


def _lm_loss_kw(cfg, seq_len: int) -> dict:
    v = tfm.padded_vocab(cfg)
    if cfg.unroll_scans:
        # cost variant: fewer, larger chunks keep the unrolled HLO
        # compilable while preserving the blockwise memory behaviour
        return dict(
            kv_chunk=min(8192, seq_len),
            q_chunk=None,
            logit_chunk=(1024 if v >= 32768 and seq_len >= 2048 else None),
        )
    return dict(
        kv_chunk=min(2048, seq_len),
        q_chunk=(2048 if seq_len > 8192 else None),
        logit_chunk=(512 if v >= 32768 and seq_len >= 2048 else None),
    )


def lm_train_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    cfg = arch.model
    b = shape.dim("global_batch")
    s = shape.dim("seq_len")
    opt = make_optimizer("adafactor", warmup_cosine(1e-3, 100, 10000))
    kw = _lm_loss_kw(cfg, s)
    step = make_train_step(
        lambda p, batch: tfm.loss_fn(p, cfg, batch, **kw), opt)

    def make_abstract():
        a_params = _lm_abstract_params(cfg)
        a_opt = jax.eval_shape(opt.init, a_params)
        a_state = {"params": a_params, "opt": a_opt,
                   "step": sds((), jnp.int32)}
        a_batch = {"tokens": sds((b, s), jnp.int32),
                   "labels": sds((b, s), jnp.int32)}
        st_sh = shd.train_state_shardings(a_state, mesh)
        b_sh = shd.batch_shardings(
            a_batch, mesh, {"tokens": ("batch", None),
                            "labels": ("batch", None)})
        out_sh = (st_sh, None)
        return (a_state, a_batch), (st_sh, b_sh), out_sh

    return CellProgram(arch.arch_id, shape.name, "train", step,
                       make_abstract)


def lm_prefill_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    cfg = arch.model
    b = shape.dim("global_batch")
    s = shape.dim("seq_len")

    if cfg.unroll_scans:                   # cost variant (see _lm_loss_kw)
        pf_kw = dict(kv_chunk=min(8192, s), q_chunk=None)
    else:
        pf_kw = dict(kv_chunk=2048, q_chunk=(2048 if s > 8192 else None))

    def prefill(params, tokens):
        logits, _, cache = tfm.forward(
            params, cfg, tokens, return_cache=True, **pf_kw)
        return logits[:, -1, :], cache

    def make_abstract():
        a_params = _lm_abstract_params(cfg)
        a_tokens = sds((b, s), jnp.int32)
        p_sh = shd.param_shardings(a_params, mesh)
        t_sh = shd.batch_shardings({"t": a_tokens}, mesh,
                                   {"t": ("batch", None)})["t"]
        return (a_params, a_tokens), (p_sh, t_sh), None

    return CellProgram(arch.arch_id, shape.name, "serve", prefill,
                       make_abstract)


def lm_decode_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    cfg = arch.model
    b = shape.dim("global_batch")
    s = shape.dim("seq_len")
    t = tfm.cache_len(cfg, s)

    def decode(params, cache, tokens):
        return tfm.decode_step(params, cfg, cache, tokens)

    def make_abstract():
        a_params = _lm_abstract_params(cfg)
        a_cache = jax.eval_shape(
            lambda: tfm.init_cache(cfg, b, s))
        a_tokens = sds((b,), jnp.int32)
        p_sh = shd.param_shardings(a_params, mesh)
        c_sh = shd.kv_cache_shardings(a_cache, mesh)
        t_sh = shd.batch_shardings({"t": a_tokens}, mesh,
                                   {"t": ("batch",)})["t"]
        return ((a_params, a_cache, a_tokens), (p_sh, c_sh, t_sh),
                (None, c_sh))

    notes = ""
    if cfg.sliding_window is not None and t < s:
        notes = (f"rolling SWA cache: window {t} << context {s} "
                 "(the sub-quadratic long-decode path)")
    return CellProgram(arch.arch_id, shape.name, "serve", decode,
                       make_abstract, notes=notes)


# ===========================================================================
# GNN family
# ===========================================================================

def _gnn_feat_dim(shape: ShapeSpec) -> int:
    return int(shape.dim("d_feat", 16))


def _needs_pos(kind: str) -> bool:
    return kind in ("meshgraphnet", "equiformer_v2")


def _gnn_loss(kind: str, cfg, out, graph):
    """Family-appropriate loss on model output."""
    if kind in ("gcn", "pna"):
        y = graph["y"]
        mask = (y >= 0).astype(jnp.float32)
        logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, jnp.maximum(y, 0)[:, None],
                                   axis=-1)[:, 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        acc = jnp.sum((jnp.argmax(out, -1) == y) * mask) / jnp.maximum(
            jnp.sum(mask), 1.0)
        return loss, {"accuracy": acc}
    # regression heads
    y = graph["y"].astype(jnp.float32)
    if y.ndim == 1:
        y = y[:, None]
    mask = graph.get("seed_mask")
    err = jnp.square(out.astype(jnp.float32) - y)
    if mask is not None:
        m = mask.astype(jnp.float32)[:, None]
        loss = jnp.sum(err * m) / jnp.maximum(jnp.sum(m) * err.shape[-1], 1.0)
    else:
        loss = jnp.mean(err)
    return loss, {"mse": loss}


def _gnn_batch_axes(keys) -> dict:
    ax = {
        "x": ("nodes", None), "pos": ("nodes", None),
        "senders": ("edges",), "receivers": ("edges",),
        "edge_mask": ("edges",), "seed_mask": ("nodes",),
        "y": ("nodes",), "n_nodes": None,
    }
    return {k: ax.get(k) for k in keys}


def gnn_fullgraph_cell(arch: ArchSpec, shape: ShapeSpec, mesh,
                       *, minibatch: bool = False) -> CellProgram:
    cfg = arch.model
    kind = cfg.kind
    mod = GNN_MODULES[kind]
    d_in = _gnn_feat_dim(shape)

    if minibatch:
        n, e = static_budget(int(shape.dim("batch_nodes")),
                             tuple(shape.dim("fanout")))
    else:
        n = int(shape.dim("n_nodes"))
        e = int(shape.dim("n_edges"))
    n, e = pad512(n), pad512(e)

    n_out = cfg.n_classes
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 100, 10000))

    def loss_fn(params, graph):
        out = mod.apply(params, cfg, graph)
        return _gnn_loss(kind, cfg, out, graph)

    step = make_train_step(loss_fn, opt)

    def make_abstract():
        a_params = jax.eval_shape(
            lambda k: mod.init(k, cfg, d_in, n_out), jax.random.PRNGKey(0))
        a_opt = jax.eval_shape(opt.init, a_params)
        a_state = {"params": a_params, "opt": a_opt,
                   "step": sds((), jnp.int32)}
        g = {
            "x": sds((n, d_in), jnp.float32),
            "senders": sds((e,), jnp.int32),
            "receivers": sds((e,), jnp.int32),
        }
        if _needs_pos(kind):
            g["pos"] = sds((n, 3), jnp.float32)
        if kind in ("gcn", "pna"):
            g["y"] = sds((n,), jnp.int32)
        elif kind == "meshgraphnet":
            g["y"] = sds((n, 3), jnp.float32)
        else:
            g["y"] = sds((n,), jnp.float32)
        if minibatch:
            g["edge_mask"] = sds((e,), jnp.bool_)
            g["seed_mask"] = sds((n,), jnp.bool_)
        st_sh = shd.train_state_shardings(a_state, mesh)
        g_sh = shd.batch_shardings(g, mesh, _gnn_batch_axes(g.keys()))
        return (a_state, g), (st_sh, g_sh), (st_sh, None)

    return CellProgram(arch.arch_id, shape.name, "train", step,
                       make_abstract,
                       notes=("sampled-subgraph (padded static shapes)"
                              if minibatch else "full-batch"))


def gnn_molecule_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    cfg = arch.model
    kind = cfg.kind
    mod = GNN_MODULES[kind]
    b = int(shape.dim("batch"))
    n = int(shape.dim("n_nodes"))
    e = int(shape.dim("n_edges"))
    d_in = _gnn_feat_dim(shape)
    n_out = cfg.n_classes
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 100, 10000))

    def loss_fn(params, batch):
        x, s, r, gids = seg.flatten_batched_graphs(
            batch["x"], batch["senders"], batch["receivers"])
        g = {"x": x, "senders": s, "receivers": r}
        if "pos" in batch:
            g["pos"] = batch["pos"].reshape(-1, 3)
        out = mod.apply(params, cfg, g)                    # (B*N, n_out)
        per_graph = seg.scatter_mean(out, gids, b)         # (B, n_out)
        y = batch["y"]
        logp = jax.nn.log_softmax(per_graph.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        acc = jnp.mean((jnp.argmax(per_graph, -1) == y).astype(jnp.float32))
        return jnp.mean(nll), {"accuracy": acc}

    step = make_train_step(loss_fn, opt)

    def make_abstract():
        a_params = jax.eval_shape(
            lambda k: mod.init(k, cfg, d_in, n_out), jax.random.PRNGKey(0))
        a_opt = jax.eval_shape(opt.init, a_params)
        a_state = {"params": a_params, "opt": a_opt,
                   "step": sds((), jnp.int32)}
        batch = {
            "x": sds((b, n, d_in), jnp.float32),
            "senders": sds((b, e), jnp.int32),
            "receivers": sds((b, e), jnp.int32),
            "y": sds((b,), jnp.int32),
        }
        if _needs_pos(kind):
            batch["pos"] = sds((b, n, 3), jnp.float32)
        st_sh = shd.train_state_shardings(a_state, mesh)
        b_sh = shd.batch_shardings(batch, mesh, {
            "x": ("batch", None, None), "pos": ("batch", None, None),
            "senders": ("batch", None), "receivers": ("batch", None),
            "y": ("batch",)})
        return (a_state, batch), (st_sh, b_sh), (st_sh, None)

    return CellProgram(arch.arch_id, shape.name, "train", step,
                       make_abstract, notes="batched small graphs")


# ===========================================================================
# recsys (FM)
# ===========================================================================

def fm_train_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    cfg = arch.model
    b = int(shape.dim("batch"))
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 100, 10000),
                         weight_decay=0.0)
    step = make_train_step(
        lambda p, batch: fm_lib.loss_fn(p, cfg, batch), opt)

    def make_abstract():
        a_params = jax.eval_shape(
            lambda k: fm_lib.init(k, cfg), jax.random.PRNGKey(0))
        a_opt = jax.eval_shape(opt.init, a_params)
        a_state = {"params": a_params, "opt": a_opt,
                   "step": sds((), jnp.int32)}
        batch = {"ids": sds((b, cfg.n_sparse), jnp.int32),
                 "y": sds((b,), jnp.int32)}
        st_sh = shd.train_state_shardings(a_state, mesh)
        b_sh = shd.batch_shardings(batch, mesh, {
            "ids": ("batch", None), "y": ("batch",)})
        return (a_state, batch), (st_sh, b_sh), (st_sh, None)

    return CellProgram(arch.arch_id, shape.name, "train", step,
                       make_abstract)


def fm_serve_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    cfg = arch.model
    b = int(shape.dim("batch"))

    def score(params, ids):
        return fm_lib.forward(params, cfg, ids)

    def make_abstract():
        a_params = jax.eval_shape(
            lambda k: fm_lib.init(k, cfg), jax.random.PRNGKey(0))
        a_ids = sds((b, cfg.n_sparse), jnp.int32)
        p_sh = shd.param_shardings(a_params, mesh)
        i_sh = NamedSharding(mesh, shd.logical_to_spec(
            ("batch", None), mesh, shd.DEFAULT_RULES))
        return (a_params, a_ids), (p_sh, i_sh), None

    return CellProgram(arch.arch_id, shape.name, "serve", score,
                       make_abstract)


def fm_retrieval_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    cfg = arch.model
    n_cand = pad512(shape.dim("n_candidates"))

    def score(params, user_ids, cand_ids):
        return fm_lib.retrieval_score(params, cfg, user_ids, cand_ids)

    def make_abstract():
        a_params = jax.eval_shape(
            lambda k: fm_lib.init(k, cfg), jax.random.PRNGKey(0))
        a_user = sds((cfg.n_sparse - 1,), jnp.int32)
        a_cand = sds((n_cand,), jnp.int32)
        p_sh = shd.param_shardings(a_params, mesh)
        u_sh = NamedSharding(mesh, P())
        c_sh = NamedSharding(mesh, shd.logical_to_spec(
            ("candidates",), mesh, shd.DEFAULT_RULES))
        return (a_params, a_user, a_cand), (p_sh, u_sh, c_sh), None

    return CellProgram(arch.arch_id, shape.name, "serve", score,
                       make_abstract, notes="1 query x 1M candidates GEMV")


# ===========================================================================
# JEDI-net (the paper's own model)
# ===========================================================================

def jedi_infer_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    cfg = arch.model
    # §Perf cell A: batch 1000 doesn't divide the 16-way data axis and
    # would replicate onto every chip (a 15.6x memory-term regression);
    # serving pads the request batch to 1024.  The bilinear-split forward
    # is the optimized production path (paper-faithful forward_sr is the
    # baseline, measured in experiments/hillclimb_jedi.py).
    b = -(-int(shape.dim("batch")) // 1024) * 1024

    def infer(params, x):
        return inet.forward_sr_split(params, cfg, x, grid=False)

    def make_abstract():
        a_params = jax.eval_shape(
            lambda k: inet.init(k, cfg), jax.random.PRNGKey(0))
        a_x = sds((b, cfg.n_objects, cfg.n_features), jnp.float32)
        p_sh = _replicated(mesh, a_params)
        x_sh = shd.batch_shardings({"x": a_x}, mesh,
                                   {"x": ("batch", None, None)})["x"]
        return (a_params, a_x), (p_sh, x_sh), None

    return CellProgram(arch.arch_id, shape.name, "serve", infer,
                       make_abstract, notes="paper Table 3 inference path")


def jedi_train_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    cfg = arch.model
    b = int(shape.dim("batch"))
    opt = make_optimizer("adamw", warmup_cosine(2e-3, 100, 10000))
    step = make_train_step(
        lambda p, batch: inet.loss_fn(p, cfg, batch), opt)

    def make_abstract():
        a_params = jax.eval_shape(
            lambda k: inet.init(k, cfg), jax.random.PRNGKey(0))
        a_opt = jax.eval_shape(opt.init, a_params)
        a_state = {"params": a_params, "opt": a_opt,
                   "step": sds((), jnp.int32)}
        batch = {"x": sds((b, cfg.n_objects, cfg.n_features), jnp.float32),
                 "y": sds((b,), jnp.int32)}
        st_sh = shd.train_state_shardings(a_state, mesh)
        b_sh = shd.batch_shardings(batch, mesh, {
            "x": ("batch", None, None), "y": ("batch",)})
        return (a_state, batch), (st_sh, b_sh), (st_sh, None)

    return CellProgram(arch.arch_id, shape.name, "train", step,
                       make_abstract)


# ===========================================================================
# dispatch
# ===========================================================================

def build_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> CellProgram:
    fam, kind = arch.family, shape.kind
    if fam == "lm":
        if kind == "train":
            return lm_train_cell(arch, shape, mesh)
        if kind == "prefill":
            return lm_prefill_cell(arch, shape, mesh)
        if kind == "decode":
            return lm_decode_cell(arch, shape, mesh)
    if fam == "gnn":
        if kind == "full_graph":
            return gnn_fullgraph_cell(arch, shape, mesh)
        if kind == "minibatch":
            return gnn_fullgraph_cell(arch, shape, mesh, minibatch=True)
        if kind == "batched_graphs":
            return gnn_molecule_cell(arch, shape, mesh)
    if fam == "recsys":
        if kind == "recsys_train":
            return fm_train_cell(arch, shape, mesh)
        if kind == "recsys_serve":
            return fm_serve_cell(arch, shape, mesh)
        if kind == "retrieval":
            return fm_retrieval_cell(arch, shape, mesh)
    if fam == "jedi":
        if kind == "jedi_infer":
            return jedi_infer_cell(arch, shape, mesh)
        if kind == "jedi_train":
            return jedi_train_cell(arch, shape, mesh)
    raise ValueError(f"no step builder for {arch.arch_id} x {shape.name}")
