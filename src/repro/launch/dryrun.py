import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and emit roofline terms.

MUST be run as a fresh process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any jax import so the CPU platform
exposes 512 placeholder devices for ``jax.make_mesh``.

Per cell:
    1. build the step program (repro.launch.steps.build_cell),
    2. jit with in/out shardings, ``.lower()`` on ShapeDtypeStructs
       (no real allocation anywhere),
    3. ``.compile()`` — a sharding mismatch, OOM-at-compile or unsupported
       collective here is a bug in the framework,
    4. record ``memory_analysis()`` / ``cost_analysis()`` / parsed
       collective bytes into experiments/dryrun/<mesh>/<arch>__<shape>.json.

Usage:
    python -m repro.launch.dryrun                      # all cells, both meshes
    python -m repro.launch.dryrun --arch fm --shape train_batch
    python -m repro.launch.dryrun --mesh single        # 16x16 only
"""

import argparse
import json
import time
import traceback


def _cost_variant(arch, shape_name: str):
    """Clone the ArchSpec with every lax.scan unrolled (and the equiformer
    edge scan re-chunked to <= 4 trips): HLO cost analysis counts a while
    body ONCE, so the production scan-based program under-reports
    flops/bytes/collectives by the trip count.  The cost variant computes
    the same function with full counting; the production variant remains
    the compile-proof + memory-analysis artifact.

    Only families whose programs contain scans need it: LM (layer scan,
    kv-chunk scan, CE-chunk scan) and equiformer's ogb edge scan.  GCN/
    PNA/MeshGraphNet layers are Python loops (already unrolled); FM and
    JEDI-net have no scans.
    """
    import dataclasses
    model = arch.model
    if arch.family == "lm":
        return dataclasses.replace(
            arch, model=dataclasses.replace(model, unroll_scans=True))
    if arch.family == "gnn" and model.kind == "equiformer_v2" \
            and shape_name == "ogb_products":
        return dataclasses.replace(
            arch, model=dataclasses.replace(
                model, unroll_scans=True, edge_chunk=1 << 24))
    return None


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str, no_cost_variant: bool = False) -> dict:
    import jax
    from repro.configs.registry import get_arch
    from repro.launch import roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.parallel.sharding import axis_rules

    mesh = make_production_mesh(multi_pod=multi_pod)
    arch = get_arch(arch_id)
    shape = arch.shapes[shape_name]
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"

    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "chips": int(mesh.devices.size), "status": "error",
    }
    t0 = time.time()
    try:
        prog = build_cell(arch, shape, mesh)
        args, in_sh, out_sh = prog.make_abstract()
        with mesh:
            with axis_rules(mesh):
                jitted = jax.jit(prog.step_fn, in_shardings=in_sh,
                                 out_shardings=out_sh)
                lowered = jitted.lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
        rec.update(roofline.from_compiled(compiled, mesh))
        rec.update({
            "status": "ok",
            "kind": prog.kind,
            "notes": prog.notes,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
        })
        # --- cost variant: scans unrolled for complete op counting.
        # Single-pod only: the §Roofline table is single-pod per the spec;
        # the multi-pod pass is the pod-axis sharding proof.
        arch_c = (None if (multi_pod or no_cost_variant)
                  else _cost_variant(arch, shape_name))
        if arch_c is not None:
            try:
                t1 = time.time()
                prog_c = build_cell(arch_c, shape, mesh)
                args_c, in_sh_c, out_sh_c = prog_c.make_abstract()
                with mesh:
                    with axis_rules(mesh):
                        compiled_c = jax.jit(
                            prog_c.step_fn, in_shardings=in_sh_c,
                            out_shardings=out_sh_c).lower(*args_c).compile()
                cost = roofline.from_compiled(compiled_c, mesh)
                rec["roofline_scan"] = rec["roofline"]
                rec["roofline"] = cost["roofline"]
                rec["collectives"] = cost["collectives"]
                rec["cost_variant"] = {
                    "compile_s": round(time.time() - t1, 2),
                    "note": "scans unrolled for counting; memory stats "
                            "remain from the production scan variant",
                }
            except Exception as e:  # noqa: BLE001
                rec["cost_variant"] = {"error": f"{type(e).__name__}: {e}"}
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]

    if out_dir:
        os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
        path = os.path.join(out_dir, mesh_name,
                            f"{arch_id}__{shape_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--include-jedi", action="store_true",
                    help="also run the paper's own jedinet cells")
    ap.add_argument("--no-cost-variant", action="store_true",
                    help="skip the unrolled cost variant (MoE train cells "
                         "compile too slowly unrolled; their roofline rows "
                         "carry the analytic x n_layers correction instead)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs.registry import ALL_ARCHS, ASSIGNED_ARCHS, get_arch

    archs = ([args.arch] if args.arch
             else (ALL_ARCHS if args.include_jedi else ASSIGNED_ARCHS))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = 0
    for arch_id in archs:
        spec = get_arch(arch_id)
        shapes = ([args.shape] if args.shape
                  else list(spec.runnable_shapes()))
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                tag = f"{arch_id} x {shape_name} @ {mesh_name}"
                t0 = time.time()
                rec = run_cell(arch_id, shape_name, mp, args.out,
                               no_cost_variant=args.no_cost_variant)
                dt = time.time() - t0
                if rec["status"] == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"[ok]   {tag}: bound={r['bound']} "
                          f"step={r['step_s']*1e3:.2f}ms "
                          f"(c={r['compute_s']*1e3:.2f} "
                          f"m={r['memory_s']*1e3:.2f} "
                          f"x={r['collective_s']*1e3:.2f}) {dt:.0f}s")
                else:
                    n_fail += 1
                    print(f"[FAIL] {tag}: {rec['error']}")
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
