"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch jedinet-30p \
        --steps 300 --batch 512 --ckpt-dir /tmp/ckpt

Production behaviours exercised here at container scale:

* **Checkpoint/restart** — async checkpoint every ``--ckpt-every`` steps;
  on start, the latest checkpoint under ``--ckpt-dir`` is restored
  (elastic: onto whatever mesh exists now).
* **Preemption safety** — SIGTERM/SIGINT trigger a final synchronous
  checkpoint before exit (the SLURM/Borg preemption contract).
* **Failure injection** — ``--fail-at-step N`` raises mid-run to
  demonstrate restart-from-checkpoint (used by the fault-tolerance test).
* **Straggler mitigation** — the input pipeline runs a prefetch thread with
  a bounded queue: a slow host overlaps data generation with device steps
  instead of stalling them.
"""

from __future__ import annotations

import argparse
import queue
import signal
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def prefetch(it, depth: int = 2):
    """Bounded-queue background prefetch (straggler overlap)."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        for item in it:
            if stop.is_set():
                return
            q.put(item)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()


def make_program(arch_id: str, batch: int, lr: float):
    """(init_fn, step_fn, batch_iter, to_device) for a trainable arch."""
    from repro.configs.registry import get_arch
    from repro.training import make_optimizer, make_train_step
    from repro.training.schedule import warmup_cosine, wsd

    arch = get_arch(arch_id)

    if arch.family == "jedi":
        from repro.core import interaction_net as inet
        from repro.data.jets import jet_batches
        cfg = arch.model
        opt = make_optimizer("adamw", warmup_cosine(lr, 50, 5000))
        return (
            lambda k: inet.init(k, cfg),
            make_train_step(lambda p, b: inet.loss_fn(p, cfg, b), opt),
            jet_batches(0, batch, cfg.n_objects, cfg.n_features),
            opt,
        )
    if arch.family == "lm":
        from repro.models import transformer as tfm
        from repro.data.lm_data import lm_batches
        cfg = arch.model
        # schedule: minicpm uses its signature WSD schedule
        sched = (wsd(lr, 50, 5000) if arch.arch_id == "minicpm-2b"
                 else warmup_cosine(lr, 50, 5000))
        opt = make_optimizer("adafactor", sched)
        return (
            lambda k: tfm.init(k, cfg),
            make_train_step(
                lambda p, b: tfm.loss_fn(p, cfg, b, logit_chunk=None), opt),
            lm_batches(0, batch, 256, cfg.vocab_size),
            opt,
        )
    if arch.family == "recsys":
        from repro.models import recsys as fm_lib
        from repro.data.recsys_data import ctr_batches
        cfg = arch.model
        opt = make_optimizer("adamw", warmup_cosine(lr, 50, 5000),
                             weight_decay=0.0)
        return (
            lambda k: fm_lib.init(k, cfg),
            make_train_step(lambda p, b: fm_lib.loss_fn(p, cfg, b), opt),
            ctr_batches(0, batch, cfg.vocab_sizes),
            opt,
        )
    if arch.family == "gnn":
        from repro.models.gnn import GNN_MODULES
        from repro.data.graphs import community_graph
        from repro.launch.steps import _gnn_loss
        cfg = arch.model
        mod = GNN_MODULES[cfg.kind]
        g = community_graph(0, 4096, 16384, 64, n_classes=cfg.n_classes)
        if cfg.kind in ("meshgraphnet", "equiformer_v2"):
            rngp = np.random.RandomState(1)
            g["pos"] = rngp.normal(0, 1, (4096, 3)).astype(np.float32)
            if cfg.kind == "meshgraphnet":
                g["y"] = np.tanh(g["pos"]).astype(np.float32)
            else:
                g["y"] = np.tanh(g["pos"]).sum(-1).astype(np.float32)
        opt = make_optimizer("adamw", warmup_cosine(lr, 50, 5000))

        def loss_fn(p, batch):
            out = mod.apply(p, cfg, batch)
            return _gnn_loss(cfg.kind, cfg, out, batch)

        def rep(d):
            while True:
                yield d

        return (
            lambda k: mod.init(k, cfg, 64, cfg.n_classes),
            make_train_step(loss_fn, opt),
            rep(g),
            opt,
        )
    raise ValueError(f"no train program for {arch_id}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a crash (fault-tolerance demo)")
    args = ap.parse_args(argv)

    from repro.training import init_state

    init_fn, step_fn, batches, opt = make_program(
        args.arch, args.batch, args.lr)
    step_jit = jax.jit(step_fn)

    cm = None
    state = None
    start_step = 0
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager
        cm = CheckpointManager(args.ckpt_dir)
        if cm.latest_step() is not None:
            state, start_step = cm.restore()
            state = jax.tree_util.tree_map(jnp.asarray, state)
            print(f"[train] restored checkpoint at step {start_step}")
    if state is None:
        state = init_state(jax.random.PRNGKey(0), init_fn, opt)

    # preemption: final sync checkpoint on SIGTERM/SIGINT
    def _on_term(signum, frame):
        if cm is not None:
            s = int(state["step"])
            print(f"[train] preempted; checkpointing step {s}", flush=True)
            cm.wait()
            cm.save(s, state)
        sys.exit(143)

    signal.signal(signal.SIGTERM, _on_term)

    it = prefetch(batches)
    t0 = time.time()
    for i in range(start_step, args.steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if args.fail_at_step is not None and i == args.fail_at_step:
            raise RuntimeError(f"injected failure at step {i}")
        state, metrics = step_jit(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            rate = (i - start_step + 1) / (time.time() - t0)
            print(f"[train] step {i} " +
                  " ".join(f"{k}={v:.4g}" for k, v in sorted(m.items()))
                  + f" ({rate:.1f} it/s)", flush=True)
        if cm is not None and i > start_step and i % args.ckpt_every == 0:
            cm.save_async(i, state)
    if cm is not None:
        cm.wait()
        cm.save(args.steps, state)
        print(f"[train] final checkpoint at step {args.steps}")


if __name__ == "__main__":
    main()
