"""Factorization Machine (Rendle, ICDM'10) over giant sparse embedding tables.

The hot path of any recsys model is the embedding lookup.  JAX has no native
``EmbeddingBag`` and no CSR sparse — per the assignment this substrate is
built from ``jnp.take`` + ``jax.ops.segment_sum``:

* All 39 per-field tables are concatenated into ONE row-sharded table
  (the FBGEMM "table-batched embedding" layout) with static per-field row
  offsets, so a batch of (B, F) ids becomes a single gather — one
  all-to-all on a ``table_rows``-sharded mesh instead of 39.
* ``embedding_bag`` provides the general multi-hot (ragged) reduction used
  by bag-valued fields: gather + segment_sum/mean, the JAX EmbeddingBag.

The FM pairwise interaction uses the O(nk) sum-square identity

    sum_{i<j} <v_i, v_j> x_i x_j = 1/2 * sum_k [ (sum_i v_ik)^2 - sum_i v_ik^2 ]

which is the paper-analogous *strength reduction*: the naive O(F^2 k)
pairwise MMM degenerates into two reductions — same insight as LL-GNN's
MMM elimination, applied to the FM kernel.  A Pallas version of this op
(fused with the logit reduction) lives in repro/kernels/fm_interaction.

``retrieval_score`` scores one query against N candidate items as a single
GEMV over the candidate embedding block (never a loop), for the
retrieval_cand cell.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.parallel.sharding import constrain


def field_offsets(cfg: RecsysConfig) -> np.ndarray:
    """Static row offset of each field inside the concatenated table.

    int32 covers tables up to 2.1B rows; beyond that, enable x64 and bump
    this dtype (the gather itself is dtype-agnostic).
    """
    sizes = np.asarray(cfg.vocab_sizes, dtype=np.int64)
    assert sizes.shape[0] == cfg.n_sparse, (sizes.shape, cfg.n_sparse)
    assert sizes.sum() < 2**31, "int32 row index overflow; enable x64"
    return np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)


def padded_rows(cfg: RecsysConfig, multiple: int = 1024) -> int:
    """Table rows rounded up so row-sharding divides any production mesh
    (512 chips); the pad rows are dead weight never indexed."""
    return -(-cfg.total_rows // multiple) * multiple


def init(key, cfg: RecsysConfig):
    rows = padded_rows(cfg)
    k1, k2 = jax.random.split(key)
    pd = jnp.dtype(cfg.param_dtype)
    return {
        # factor table V: (rows, k). Init scale 1/sqrt(k) keeps the pairwise
        # term O(1) at init.
        "tables": {"rows": (jax.random.normal(k1, (rows, cfg.embed_dim),
                                              jnp.float32)
                            * (1.0 / np.sqrt(cfg.embed_dim))).astype(pd) * 0.01},
        # linear weights w: one scalar per row (kept as a (rows, 1) column so
        # the same row-sharding rule applies).
        "linear": {"rows": jnp.zeros((rows, 1), pd)},
        "bias": jnp.zeros((), pd),
    }


# ---------------------------------------------------------------------------
# embedding substrate
# ---------------------------------------------------------------------------

def lookup(params, cfg: RecsysConfig, ids):
    """ids: (B, F) per-field local ids -> (v (B, F, K), w (B, F))."""
    offs = jnp.asarray(field_offsets(cfg))
    flat = ids.astype(jnp.int32) + offs[None, :]
    v = jnp.take(params["tables"]["rows"], flat, axis=0)     # (B, F, K)
    w = jnp.take(params["linear"]["rows"], flat, axis=0)[..., 0]
    return v, w


def embedding_bag(table, indices, segment_ids, n_segments: int,
                  mode: str = "sum", weights=None):
    """JAX EmbeddingBag: ragged multi-hot lookup + per-bag reduction.

    table: (rows, K); indices: (nnz,) row ids; segment_ids: (nnz,) bag id of
    each index (sorted or not); returns (n_segments, K).
    """
    g = jnp.take(table, indices, axis=0)                     # (nnz, K)
    if weights is not None:
        g = g * weights[:, None].astype(g.dtype)
    s = jax.ops.segment_sum(g, segment_ids, num_segments=n_segments)
    if mode == "sum":
        return s
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(segment_ids, dtype=g.dtype),
                                  segment_ids, num_segments=n_segments)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        m = jax.ops.segment_max(g, segment_ids, num_segments=n_segments)
        return jnp.where(jnp.isfinite(m), m, 0.0)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# FM forward
# ---------------------------------------------------------------------------

def fm_interaction(v):
    """Sum-square strength reduction. v: (..., F, K) -> (...,) scalar term."""
    sum_v = jnp.sum(v, axis=-2)                               # (..., K)
    sum_sq = jnp.sum(jnp.square(v), axis=-2)                  # (..., K)
    return 0.5 * jnp.sum(jnp.square(sum_v) - sum_sq, axis=-1)


def forward(params, cfg: RecsysConfig, ids, *, use_kernel: bool = False,
            interpret: bool = False):
    """ids: (B, F) -> logits (B,)."""
    v, w = lookup(params, cfg, ids)
    v = constrain(v, "batch", None, None)
    if use_kernel:
        from repro.kernels.fm_interaction import ops as fm_ops
        inter = fm_ops.fm_interaction(v, interpret=interpret)
    else:
        inter = fm_interaction(v.astype(jnp.float32))
    linear = jnp.sum(w.astype(jnp.float32), axis=-1)
    return linear + inter + params["bias"].astype(jnp.float32)


def loss_fn(params, cfg: RecsysConfig, batch, **kw):
    """Binary logistic loss. batch: {ids (B, F), y (B,) in {0,1}}."""
    logits = forward(params, cfg, batch["ids"], **kw)
    y = batch["y"].astype(jnp.float32)
    # numerically stable BCE-with-logits
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean(((logits > 0) == (y > 0.5)).astype(jnp.float32))
    return loss, {"accuracy": acc}


# ---------------------------------------------------------------------------
# retrieval: 1 query x N candidates
# ---------------------------------------------------------------------------

def retrieval_score(params, cfg: RecsysConfig, user_ids, cand_ids):
    """Score one query against a large candidate set, as one GEMV.

    user_ids: (F,) the query's field ids; cand_ids: (N,) candidate ids in the
    LAST field's vocabulary (the "item" field).  FM score decomposes as

        s(u, c) = const(u) + w_c + <sum_f v_f(u), v_c>

    so scoring N candidates is a (N, K) @ (K,) matvec — never a loop.
    """
    offs = jnp.asarray(field_offsets(cfg))
    u_rows = user_ids.astype(jnp.int32) + offs[:-1]           # user fields
    vu = jnp.take(params["tables"]["rows"], u_rows, axis=0)   # (F-1, K)
    wu = jnp.take(params["linear"]["rows"], u_rows, axis=0)[..., 0]

    vu32 = vu.astype(jnp.float32)
    q = jnp.sum(vu32, axis=0)                                 # (K,) query vec
    const_u = (jnp.sum(wu.astype(jnp.float32))
               + fm_interaction(vu32)
               + params["bias"].astype(jnp.float32))

    c_rows = cand_ids.astype(jnp.int32) + offs[-1]
    vc = jnp.take(params["tables"]["rows"], c_rows, axis=0)   # (N, K)
    vc = constrain(vc, "candidates", None)
    wc = jnp.take(params["linear"]["rows"], c_rows, axis=0)[..., 0]
    scores = vc.astype(jnp.float32) @ q + wc.astype(jnp.float32) + const_u
    return constrain(scores, "candidates")
