"""Decoder-only LM family: dense + MoE, GQA, RoPE, SWA, scan-over-layers.

Covers the five assigned LM architectures (arctic-480b, moonshot-v1-16b-a3b,
h2o-danube-1.8b, minicpm-2b, phi3-medium-14b) from one implementation:

* pre-RMSNorm blocks, RoPE GQA attention (sliding-window for h2o-danube),
  SwiGLU FFN or sort-based MoE (+ Arctic's parallel dense residual FFN);
* layers are scan-stacked (one compiled block regardless of depth — critical
  for dry-run compile times at 512 fake devices) with optional per-layer
  remat for training;
* three entry points matching the assigned input shapes:
    - ``forward``      : train / prefill logits (+ KV cache on request)
    - ``init_cache``   : allocate a (possibly rolling) KV cache
    - ``decode_step``  : one-token serve step against the cache.

Sharding: activations are annotated with logical axes (batch->data[,pod],
seq->model i.e. sequence parallelism on the residual stream, heads->model
inside attention, d_ff->model in the FFN); weights follow PARAM_RULES
(TP over `model` + FSDP over `data`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.models import moe as moe_lib
from repro.nn import core as nn
from repro.nn.attention import attention
from repro.nn.rope import apply_rope, rope_cos_sin
from repro.parallel.sharding import constrain


def padded_vocab(cfg: TransformerConfig, multiple: int = 256) -> int:
    return -(-cfg.vocab_size // multiple) * multiple


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: TransformerConfig):
    d, hd = cfg.d_model, cfg.head_dim_
    n_q, n_kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    pd = jnp.dtype(cfg.param_dtype)

    def w(k, shape, fan_in):
        std = (2.0 / fan_in) ** 0.5
        return {"w": (jax.random.normal(k, shape, jnp.float32) * std).astype(pd)}

    layer = {
        "attn_norm": nn.rmsnorm_init(d, dtype=pd),
        "attn": {
            "wq": w(ks[0], (d, n_q * hd), d),
            "wk": w(ks[1], (d, n_kv * hd), d),
            "wv": w(ks[2], (d, n_kv * hd), d),
            "wo": w(ks[3], (n_q * hd, d), n_q * hd),
        },
        "ffn_norm": nn.rmsnorm_init(d, dtype=pd),
    }
    if cfg.moe is not None:
        layer["moe"] = moe_lib.init_moe(ks[4], cfg.moe, d, cfg.d_ff, dtype=pd)
        if cfg.moe.dense_residual:
            layer["ffn"] = {
                "w_gate": w(ks[5], (d, cfg.d_ff), d),
                "w_in": w(ks[6], (d, cfg.d_ff), d),
                "w_out": w(ks[7], (cfg.d_ff, d), cfg.d_ff),
            }
    else:
        layer["ffn"] = {
            "w_gate": w(ks[5], (d, cfg.d_ff), d),
            "w_in": w(ks[6], (d, cfg.d_ff), d),
            "w_out": w(ks[7], (cfg.d_ff, d), cfg.d_ff),
        }
    return layer


def init(key, cfg: TransformerConfig):
    km, kl, kh = jax.random.split(key, 3)
    pd = jnp.dtype(cfg.param_dtype)
    v = padded_vocab(cfg)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    # scan-stacked layer params: every leaf gains a leading n_layers axis.
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    params = {
        "embed": {"w": (jax.random.normal(km, (v, cfg.d_model), jnp.float32)
                        * 0.02).astype(pd)},
        "layers": layers,
        "final_norm": nn.rmsnorm_init(cfg.d_model, dtype=pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": (jax.random.normal(kh, (cfg.d_model, v),
                                                     jnp.float32)
                                   * 0.02).astype(pd)}
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_block(lp, cfg: TransformerConfig, h, q_pos, *, cache=None,
                kv_chunk=None, q_chunk=None):
    """h: (B, S, d) -> (attn_out, kv).

    Without a cache: self-attention over h's own (rope'd) keys; kv is the
    (k, v) pair for prefill cache building.  With ``cache = (k_cache,
    v_cache, slot_pos, write_idx)``: writes this step's k/v into the cache
    slot and attends over the full cache (decode path); kv is the updated
    cache pair.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, d = h.shape
    hd, n_q, n_kv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads

    x = nn.rmsnorm_apply(lp["attn_norm"], h)
    q = nn.dense_apply(lp["attn"]["wq"], x, compute_dtype=cdt)
    k = nn.dense_apply(lp["attn"]["wk"], x, compute_dtype=cdt)
    v = nn.dense_apply(lp["attn"]["wv"], x, compute_dtype=cdt)
    q = q.reshape(b, s, n_q, hd)
    k = k.reshape(b, s, n_kv, hd)
    v = v.reshape(b, s, n_kv, hd)

    cos, sin = rope_cos_sin(q_pos, hd, cfg.rope_theta, dtype=jnp.float32)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)

    if cache is not None:
        k_cache, v_cache, slot_pos, write_idx = cache
        bidx = jnp.arange(b)
        k_all = k_cache.at[bidx, write_idx].set(k[:, 0].astype(k_cache.dtype))
        v_all = v_cache.at[bidx, write_idx].set(v[:, 0].astype(v_cache.dtype))
        kv_pos, kv_out = slot_pos, (k_all, v_all)
    else:
        k_all, v_all, kv_pos, kv_out = k, v, q_pos, (k, v)

    o = attention(q, k_all, v_all, q_pos=q_pos, kv_pos=kv_pos,
                  causal=True, window=cfg.sliding_window,
                  kv_chunk=kv_chunk, q_chunk=q_chunk,
                  unroll=cfg.unroll_scans)
    o = constrain(o, "batch", None, "heads", None)
    out = nn.dense_apply(lp["attn"]["wo"], o.reshape(b, s, n_q * hd),
                         compute_dtype=cdt)
    return out, kv_out


def _dense_ffn(lp, cfg: TransformerConfig, x):
    cdt = jnp.dtype(cfg.compute_dtype)
    act = nn.ACTIVATIONS[cfg.activation]
    g = nn.dense_apply(lp["w_gate"], x, compute_dtype=cdt)
    u = nn.dense_apply(lp["w_in"], x, compute_dtype=cdt)
    mid = act(g) * u
    mid = constrain(mid, "batch", None, "mlp")
    return nn.dense_apply(lp["w_out"], mid, compute_dtype=cdt)


def _layer_fn(lp, cfg: TransformerConfig, h, q_pos, *, cache=None,
              kv_chunk=None, q_chunk=None):
    """One transformer block. Returns (h, kv, aux_loss)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    attn_out, kv = _attn_block(lp, cfg, h, q_pos, cache=cache,
                               kv_chunk=kv_chunk, q_chunk=q_chunk)
    h = h + attn_out
    h = constrain(h, "batch", "seq", None)

    x = nn.rmsnorm_apply(lp["ffn_norm"], h)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        b, s, d = x.shape
        xt = x.reshape(b * s, d)
        xt = constrain(xt, "tokens", None)
        y, aux = moe_lib.moe_apply(lp["moe"], cfg.moe, xt, compute_dtype=cdt,
                                   activation=cfg.activation)
        y = y.reshape(b, s, d)
        if cfg.moe.dense_residual:
            y = y + _dense_ffn(lp["ffn"], cfg, x)
    else:
        y = _dense_ffn(lp["ffn"], cfg, x)
    h = h + y.astype(h.dtype)
    h = constrain(h, "batch", "seq", None)
    return h, kv, aux


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: TransformerConfig, tokens, *, return_cache=False,
            kv_chunk=2048, q_chunk=None):
    """tokens: (B, S) int32 -> logits (B, S, vocab_padded) [+ cache]."""
    cdt = jnp.dtype(cfg.compute_dtype)
    out = forward_hidden(params, cfg, tokens, return_cache=return_cache,
                         kv_chunk=kv_chunk, q_chunk=q_chunk)
    h = out[0]
    logits = h @ _head_weight(params, cfg, cdt)
    logits = constrain(logits, "tokens", None, None)
    if return_cache:
        return logits, out[1], out[2]
    return logits, out[1]


def forward_hidden(params, cfg: TransformerConfig, tokens, *,
                   return_cache=False, kv_chunk=2048, q_chunk=None):
    """tokens: (B, S) int32 -> (h (B, S, d), aux_loss [, cache])."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    h = jnp.take(params["embed"]["w"], tokens, axis=0).astype(cdt)
    h = constrain(h, "batch", "seq", None)
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, lp):
        out, kv_new, aux = _layer_fn(lp, cfg, h, q_pos,
                                     kv_chunk=kv_chunk, q_chunk=q_chunk)
        return out, (kv_new if return_cache else None, aux)

    if cfg.remat == "layer":
        body = jax.checkpoint(body)

    h, (cache_kv, aux) = jax.lax.scan(
        body, h, params["layers"],
        unroll=cfg.n_layers if cfg.unroll_scans else 1)
    h = nn.rmsnorm_apply(params["final_norm"], h)
    aux_total = jnp.sum(aux)
    if return_cache:
        k_stack, v_stack = cache_kv                     # (L, B, S, n_kv, hd)
        cache = {"k": k_stack, "v": v_stack,
                 "pos": jnp.full((b,), s, jnp.int32)}
        return h, aux_total, cache
    return h, aux_total


def _head_weight(params, cfg: TransformerConfig, cdt):
    w = (params["embed"]["w"].T if cfg.tie_embeddings
         else params["lm_head"]["w"])
    return w.astype(cdt)


def _chunk_nll(h_chunk, labels_chunk, head_w):
    """CE for one (B, sc, d) hidden chunk without keeping fp32 logits."""
    logits = (h_chunk @ head_w).astype(jnp.float32)     # (B, sc, V)
    logits = constrain(logits, "tokens", None, None)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels_chunk, 0)[..., None], axis=-1)[..., 0]
    mask = (labels_chunk >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask), jnp.sum(mask)


def loss_fn(params, cfg: TransformerConfig, batch, *, kv_chunk=2048,
            q_chunk=None, logit_chunk=None):
    """Next-token cross-entropy; labels = batch["labels"] (B, S), -1 ignored.

    ``logit_chunk`` streams the LM head + CE over sequence chunks so the
    (B, S, V) fp32 logits never materialize — at vocab 100k and 1M tokens
    that tensor is ~400 GB fp32, the single largest activation of the train
    cells.  Each chunk is remat'd (logits recomputed in backward), trading
    one extra head GEMM for the memory.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    h, aux = forward_hidden(params, cfg, batch["tokens"],
                            kv_chunk=kv_chunk, q_chunk=q_chunk)
    labels = batch["labels"]
    head_w = _head_weight(params, cfg, cdt)
    b, s = labels.shape

    if logit_chunk is None or s <= logit_chunk:
        nll_sum, n_tok = _chunk_nll(h, labels, head_w)
    else:
        assert s % logit_chunk == 0, (s, logit_chunk)
        nc = s // logit_chunk
        h_c = jnp.moveaxis(
            h.reshape(b, nc, logit_chunk, h.shape[-1]), 1, 0)
        l_c = jnp.moveaxis(labels.reshape(b, nc, logit_chunk), 1, 0)

        def body(carry, xs):
            hh, ll = xs
            ns, nt = jax.checkpoint(_chunk_nll)(hh, ll, head_w)
            return (carry[0] + ns, carry[1] + nt), None

        (nll_sum, n_tok), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (h_c, l_c), unroll=nc if cfg.unroll_scans else 1)

    loss = nll_sum / jnp.maximum(n_tok, 1.0)
    return loss + aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: cache + decode
# ---------------------------------------------------------------------------

def cache_len(cfg: TransformerConfig, max_seq: int) -> int:
    """Rolling window for SWA archs — the sub-quadratic long-context path."""
    if cfg.sliding_window is not None:
        return min(max_seq, cfg.sliding_window)
    return max_seq


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=None):
    t = cache_len(cfg, max_seq)
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    shape = (cfg.n_layers, batch, t, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        # absolute position of each slot's entry; -1 = empty
        "slot_pos": jnp.full((batch, t), -1, jnp.int32),
        "pos": jnp.zeros((batch,), jnp.int32),     # next position to write
    }


def decode_step(params, cfg: TransformerConfig, cache, tokens):
    """One greedy decode step. tokens: (B,) int32 -> (logits (B, V), cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b = tokens.shape[0]
    t = cache["k"].shape[2]
    cur = cache["pos"]                                  # (B,)
    write_idx = cur % t                                 # rolling for SWA
    h = jnp.take(params["embed"]["w"], tokens[:, None], axis=0).astype(cdt)
    h = constrain(h, "batch", None, None)
    q_pos = cur[:, None]

    new_slot_pos = cache["slot_pos"].at[jnp.arange(b), write_idx].set(cur)

    def body(h, xs):
        lp, k_c, v_c = xs
        out, (k_new, v_new), _ = _layer_fn(
            lp, cfg, h, q_pos,
            cache=(k_c, v_c, new_slot_pos, write_idx))
        return out, (k_new, v_new)

    h, (k_upd, v_upd) = jax.lax.scan(
        body, h, (params["layers"], cache["k"], cache["v"]),
        unroll=cfg.n_layers if cfg.unroll_scans else 1)
    h = nn.rmsnorm_apply(params["final_norm"], h)
    head_w = (params["embed"]["w"].T if cfg.tie_embeddings
              else params["lm_head"]["w"])
    logits = (h @ head_w.astype(cdt))[:, 0, :]
    new_cache = {"k": k_upd, "v": v_upd, "slot_pos": new_slot_pos,
                 "pos": cur + 1}
    return logits.astype(jnp.float32), new_cache
