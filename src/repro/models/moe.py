"""Mixture-of-Experts layer: sort-based top-k dispatch with capacity.

This is the TPU-idiomatic "dropping" MoE (as used by MaxText / GShard
successors): instead of a (tokens x experts x capacity) one-hot dispatch
einsum — infeasible at 1M tokens x 128 experts — token assignments are
argsorted by expert id, positioned within their expert via a first-occurrence
subtraction (O(T k log Tk), no T x E cumsum), scattered into an
(E x capacity x d) buffer, processed with a batched per-expert SwiGLU einsum,
and combined back with the gate weights.  Tokens beyond an expert's capacity
are dropped (their residual path passes through), matching reference MoE
training semantics with capacity_factor ~ 1.25.

Sharding: the expert axis maps to the `data` mesh axis (expert parallelism)
and each expert's d_ff to `model` (tensor parallelism); the token->slot
scatter becomes the all-to-all that EP requires.  An Arctic-style dense
residual branch runs in parallel and is summed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.nn import core as nn
from repro.parallel.sharding import constrain


def init_moe(key, moe: MoEConfig, d_model: int, d_ff: int, *,
             dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    e = moe.n_experts
    std_in = (2.0 / d_model) ** 0.5
    std_out = (2.0 / d_ff) ** 0.5

    def w(k, shape, std):
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)

    params = {
        "router": {"w": w(ks[0], (d_model, e), std_in)},
        "experts": {
            "w_gate": w(ks[1], (e, d_model, d_ff), std_in),
            "w_in": w(ks[2], (e, d_model, d_ff), std_in),
            "w_out": w(ks[3], (e, d_ff, d_model), std_out),
        },
    }
    return params


def capacity(n_tokens: int, moe: MoEConfig) -> int:
    c = int(n_tokens * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8 for tiling


def moe_apply(params, moe: MoEConfig, x, *, compute_dtype=jnp.bfloat16,
              activation: str = "silu"):
    """x: (T, d) token-major. Returns (out (T, d), aux_loss scalar)."""
    t, d = x.shape
    e, k = moe.n_experts, moe.top_k
    c = capacity(t, moe)
    act = nn.ACTIVATIONS[activation]

    xc = x.astype(compute_dtype)
    router_logits = (xc @ params["router"]["w"].astype(compute_dtype)
                     ).astype(jnp.float32)                     # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # --- load-balancing auxiliary loss (Switch eq. 4) ---
    me = jnp.mean(probs, axis=0)                               # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = moe.aux_loss_weight * e * jnp.sum(me * ce)

    # --- sort-based dispatch ---
    flat_e = expert_ids.reshape(-1)                            # (T*k,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    first_occ = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(t * k, dtype=jnp.int32) - first_occ.astype(jnp.int32)
    keep = pos < c
    slot = jnp.where(keep, sorted_e * c + pos, e * c)          # drop -> last
    token_idx = (sort_idx // k).astype(jnp.int32)

    buf = jnp.zeros((e * c + 1, d), compute_dtype)
    # NB: dropped assignments all land on the sentinel row e*c, so indices
    # are NOT unique — do not pass unique_indices here.
    # §Perf cell B: the per-assignment gather output is constrained to the
    # token sharding so SPMD keeps it distributed; without this the
    # partitioner resolves the gather as partial-result + full-output
    # all-reduce (51.5 GB/device/layer at 1M tokens x d=2048 fp32).
    dispatched = constrain(xc[token_idx], "tokens", None)
    buf = buf.at[slot].set(dispatched, mode="drop")
    h = buf[: e * c].reshape(e, c, d)
    h = constrain(h, "expert", "expert_slot", None)

    # --- per-expert SwiGLU ---
    wg = params["experts"]["w_gate"].astype(compute_dtype)
    wi = params["experts"]["w_in"].astype(compute_dtype)
    wo = params["experts"]["w_out"].astype(compute_dtype)
    hg = jnp.einsum("ecd,edf->ecf", h, wg)
    hi = jnp.einsum("ecd,edf->ecf", h, wi)
    hmid = act(hg) * hi
    hmid = constrain(hmid, "expert", "expert_slot", "mlp")
    y = jnp.einsum("ecf,efd->ecd", hmid, wo)
    y = constrain(y, "expert", "expert_slot", None)

    # --- combine ---
    y_flat = jnp.concatenate(
        [y.reshape(e * c, d), jnp.zeros((1, d), y.dtype)], axis=0)
    per_assign = constrain(y_flat[slot], "tokens", None)       # (T*k, d)
    gates_sorted = gate_vals.reshape(-1)[sort_idx].astype(y.dtype)
    # fp32 scatter-add accumulation, but the gathered payload stays in
    # compute dtype — the weighted sum over <= top_k values is short.
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[token_idx].add(
        (per_assign * gates_sorted[:, None]).astype(jnp.float32))
    out = constrain(out, "tokens", None)
    return out.astype(compute_dtype), aux
