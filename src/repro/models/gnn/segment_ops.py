"""Message-passing primitives: edge-index gather + segment reductions.

JAX has no CSR/CSC sparse or native EmbeddingBag — per the assignment, the
message-passing substrate is built from ``jnp.take`` + ``jax.ops.segment_*``
over an edge-index list.  This module is that substrate:

* ``gather(x, idx)``                  — edge <- node gather
* ``scatter_{sum,mean,max,min,std}``  — node <- edge segment reductions
* ``segment_softmax``                 — edge-softmax over incoming edges
  (GAT/Equiformer attention)
* ``degrees``                         — in/out degree via segment_sum

Sharding note: edges are sharded over the full chip set ("edges" logical
axis); ``segment_sum`` into node arrays lowers to scatter-adds which the
SPMD partitioner turns into the gather/all-reduce pattern of distributed
message passing.  The strength-reduction insight of the paper (Sec 3.1)
shows up here as a *special case*: for the fully-connected receiver-major
JEDI-net graph these segment ops collapse to reshapes (see
repro/core/adjacency.py) — the general substrate below is what the four
assigned GNN architectures use.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather(x, idx):
    """x: (N, ...), idx: (E,) -> (E, ...)."""
    return jnp.take(x, idx, axis=0)


def scatter_sum(msgs, seg_ids, n: int):
    return jax.ops.segment_sum(msgs, seg_ids, num_segments=n)


def scatter_mean(msgs, seg_ids, n: int):
    s = scatter_sum(msgs, seg_ids, n)
    cnt = jax.ops.segment_sum(jnp.ones((msgs.shape[0],), msgs.dtype),
                              seg_ids, num_segments=n)
    return s / jnp.maximum(cnt, 1.0)[(...,) + (None,) * (msgs.ndim - 1)]


def scatter_max(msgs, seg_ids, n: int):
    """Per-segment max; empty segments yield 0 (not -inf) so isolated
    nodes don't poison downstream MLPs."""
    m = jax.ops.segment_max(msgs, seg_ids, num_segments=n)
    return jnp.where(jnp.isfinite(m), m, 0.0).astype(msgs.dtype)


def scatter_min(msgs, seg_ids, n: int):
    return -scatter_max(-msgs, seg_ids, n)


def scatter_std(msgs, seg_ids, n: int, *, eps: float = 1e-5):
    """Per-segment standard deviation (PNA's 4th aggregator)."""
    mean = scatter_mean(msgs, seg_ids, n)
    sq = scatter_mean(jnp.square(msgs), seg_ids, n)
    var = jnp.maximum(sq - jnp.square(mean), 0.0)
    return jnp.sqrt(var + eps)


SCATTER = {
    "sum": scatter_sum,
    "mean": scatter_mean,
    "max": scatter_max,
    "min": scatter_min,
    "std": scatter_std,
}


def degrees(seg_ids, n: int, dtype=jnp.float32):
    return jax.ops.segment_sum(jnp.ones(seg_ids.shape, dtype), seg_ids,
                               num_segments=n)


def segment_softmax(scores, seg_ids, n: int):
    """Softmax of edge scores within each receiver segment.

    scores: (E, ...) -> (E, ...), normalized over edges sharing seg_id.
    """
    smax = jax.ops.segment_max(scores, seg_ids, num_segments=n)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = jnp.exp(scores - jnp.take(smax, seg_ids, axis=0))
    den = jax.ops.segment_sum(ex, seg_ids, num_segments=n)
    return ex / jnp.maximum(jnp.take(den, seg_ids, axis=0), 1e-20)


def flatten_batched_graphs(x, senders, receivers):
    """(B, N, F) batched small graphs -> one big disjoint graph.

    Returns (x_flat (B*N, F), senders_flat, receivers_flat, graph_ids (B*N,)).
    Standard offset trick: edge indices of graph b get + b*N.
    """
    b, n = x.shape[0], x.shape[1]
    e = senders.shape[1]
    offs = (jnp.arange(b, dtype=senders.dtype) * n)[:, None]
    s_flat = (senders + offs).reshape(b * e)
    r_flat = (receivers + offs).reshape(b * e)
    graph_ids = jnp.repeat(jnp.arange(b, dtype=jnp.int32), n)
    return x.reshape(b * n, *x.shape[2:]), s_flat, r_flat, graph_ids
