from repro.models.gnn import segment_ops, gcn, pna, meshgraphnet, equiformer_v2

GNN_MODULES = {
    "gcn": gcn,
    "pna": pna,
    "meshgraphnet": meshgraphnet,
    "equiformer_v2": equiformer_v2,
}
