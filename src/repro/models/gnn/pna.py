"""PNA — Principal Neighbourhood Aggregation (arXiv:2004.05718).

Per layer: edge message MLP(h_src || h_dst) -> 4 aggregators
(mean/max/min/std) x 3 degree scalers (identity / amplification log(d+1)/δ /
attenuation δ/log(d+1)) -> concat (12 x d) -> post linear + residual.
δ is the mean log-degree of the training graph (estimated online here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.models.gnn import segment_ops as seg
from repro.nn import core as nn
from repro.parallel.sharding import constrain

SCALERS = ("identity", "amplification", "attenuation")


def init(key, cfg: GNNConfig, d_in: int, n_out: int):
    d = cfg.d_hidden
    n_agg = len(cfg.aggregators) * len(cfg.scalers)
    keys = jax.random.split(key, 3 + cfg.n_layers * 2)
    params = {
        "gnn_encoder": nn.dense_init(keys[0], d_in, d),
        "gnn_layers": [],
        "gnn_decoder": nn.dense_init(keys[1], d, n_out),
    }
    for i in range(cfg.n_layers):
        params["gnn_layers"].append({
            "msg": nn.dense_init(keys[2 + 2 * i], 2 * d, d),
            "post": nn.dense_init(keys[3 + 2 * i], n_agg * d, d),
        })
    return params


def _scale(agg, scaler: str, logdeg, delta):
    if scaler == "identity":
        return agg
    if scaler == "amplification":
        return agg * (logdeg / delta)
    if scaler == "attenuation":
        return agg * (delta / jnp.maximum(logdeg, 1e-5))
    raise ValueError(scaler)


def apply(params, cfg: GNNConfig, graph):
    x = graph["x"]
    s, r = graph["senders"], graph["receivers"]
    n = x.shape[0]
    act = nn.ACTIVATIONS[cfg.activation]

    deg = seg.degrees(r, n)
    logdeg = jnp.log1p(deg)[:, None]
    delta = jnp.maximum(jnp.mean(logdeg), 1e-5)

    h = act(nn.dense_apply(params["gnn_encoder"], x))
    h = constrain(h, "nodes", None)
    for lp in params["gnn_layers"]:
        hs, hr = seg.gather(h, s), seg.gather(h, r)
        m = act(nn.dense_apply(lp["msg"], jnp.concatenate([hs, hr], -1)))
        m = constrain(m, "edges", None)
        aggs = []
        for agg_name in cfg.aggregators:
            a = seg.SCATTER[agg_name](m, r, n)
            for scaler in cfg.scalers:
                aggs.append(_scale(a, scaler, logdeg, delta))
        z = jnp.concatenate(aggs, axis=-1)
        h = h + act(nn.dense_apply(lp["post"], z))
        h = constrain(h, "nodes", None)
    return nn.dense_apply(params["gnn_decoder"], h)
