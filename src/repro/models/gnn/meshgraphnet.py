"""MeshGraphNet (arXiv:2010.03409): encode-process-decode with edge features.

Encoder: node MLP + edge MLP (edge features = relative position, |dx|, plus
any provided edge attributes).  Processor: n_layers message-passing blocks,
each with an edge-update MLP(e, h_src, h_dst) and node-update MLP(h, sum_e)
with residuals and LayerNorm (the paper's configuration: 15 blocks, width
128, 2-layer MLPs).  Decoder: node MLP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.models.gnn import segment_ops as seg
from repro.nn import core as nn
from repro.parallel.sharding import constrain


def _mlp_init(key, d_in, d_hidden, d_out, n_layers, *, with_ln=True):
    hidden = [d_hidden] * max(n_layers - 1, 1)
    p = {"mlp": nn.mlp_init(key, d_in, hidden, d_out)}
    if with_ln:
        p["ln"] = nn.layernorm_init(d_out)
    return p


def _mlp_apply(p, x, activation):
    y = nn.mlp_apply(p["mlp"], x, activation=activation)
    if "ln" in p:
        y = nn.layernorm_apply(p["ln"], y)
    return y


def edge_geometry(graph):
    """Relative displacement + distance as base edge features."""
    pos = graph.get("pos")
    s, r = graph["senders"], graph["receivers"]
    feats = []
    if pos is not None:
        dx = seg.gather(pos, s) - seg.gather(pos, r)
        feats += [dx, jnp.linalg.norm(dx, axis=-1, keepdims=True)]
    if graph.get("edge_attr") is not None:
        feats.append(graph["edge_attr"])
    if not feats:
        feats = [jnp.ones((s.shape[0], 1), jnp.float32)]
    return jnp.concatenate(feats, axis=-1)


def edge_feat_dim(graph_spec: dict) -> int:
    d = 0
    if graph_spec.get("pos") is not None:
        d += 4
    if graph_spec.get("edge_attr") is not None:
        d += graph_spec["edge_attr"].shape[-1]
    return d or 1


def init(key, cfg: GNNConfig, d_in: int, n_out: int, *, d_edge_in: int = 4):
    d, nl = cfg.d_hidden, cfg.mlp_layers
    keys = jax.random.split(key, 3 + 2 * cfg.n_layers)
    params = {
        "gnn_node_enc": _mlp_init(keys[0], d_in, d, d, nl),
        "gnn_edge_enc": _mlp_init(keys[1], d_edge_in, d, d, nl),
        "gnn_decoder": _mlp_init(keys[2], d, d, n_out, nl, with_ln=False),
        "gnn_blocks": [],
    }
    for i in range(cfg.n_layers):
        params["gnn_blocks"].append({
            "edge": _mlp_init(keys[3 + 2 * i], 3 * d, d, d, nl),
            "node": _mlp_init(keys[4 + 2 * i], 2 * d, d, d, nl),
        })
    return params


def apply(params, cfg: GNNConfig, graph):
    s, r = graph["senders"], graph["receivers"]
    n = graph["x"].shape[0]
    act = cfg.activation

    h = _mlp_apply(params["gnn_node_enc"], graph["x"], act)
    e = _mlp_apply(params["gnn_edge_enc"], edge_geometry(graph), act)
    h = constrain(h, "nodes", None)
    e = constrain(e, "edges", None)

    for blk in params["gnn_blocks"]:
        hs, hr = seg.gather(h, s), seg.gather(h, r)
        e = e + _mlp_apply(blk["edge"], jnp.concatenate([e, hs, hr], -1), act)
        e = constrain(e, "edges", None)
        agg = seg.scatter_sum(e, r, n)
        h = h + _mlp_apply(blk["node"], jnp.concatenate([h, agg], -1), act)
        h = constrain(h, "nodes", None)
    return _mlp_apply(params["gnn_decoder"], h, act)
