"""SO(3) machinery for EquiformerV2's eSCN convolution.

The eSCN trick (arXiv:2302.03655 / 2306.12059): rotate each edge's source
irreps into a frame where the edge direction is +z; there the SO(3) tensor
product degenerates into independent SO(2) mixes per |m| (O(L^3) instead of
O(L^6)), truncated at m_max.

Wigner rotation matrices are built two ways:

* ``wigner_solve``   — the oracle: for any rotation R, solve
  D^l = Y^l(R S) @ pinv(Y^l(S)) on a fixed set S of sample directions.
  Convention-free and exact to fp precision; used in tests and to
  precompute the J^l constants.
* ``wigner_align_z`` — the fast per-edge path: decompose the align-to-z
  rotation as Ry(-beta) Rz(-alpha) and use the e3nn J-matrix identity
  D_y(b) = J D_z(b) J with J^l = D^l(Ry(pi/2)) precomputed at import via
  the oracle.  Per-edge cost is two small dense matmuls per l — no expm,
  no per-edge solve.

Real spherical harmonics use the standard orthonormal basis, ordering
m = -l..l, flat index l*l + l + m; D^l is orthogonal in this basis.
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax.numpy as jnp
import numpy as np


def num_coeffs(l_max: int) -> int:
    return (l_max + 1) ** 2


def flat_index(l: int, m: int) -> int:
    return l * l + l + m


# ---------------------------------------------------------------------------
# real spherical harmonics (jnp, vmappable)
# ---------------------------------------------------------------------------

def real_sph_harm(l_max: int, dirs, xp=jnp):
    """dirs: (..., 3) unit vectors -> (..., (l_max+1)^2) real SH values.

    ``xp`` selects the array namespace: jnp on the traced fast path, np for
    the Wigner oracle constants so their lru-cached computation never
    captures tracers when first touched inside a jit trace.
    """
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    rxy = xp.sqrt(xp.maximum(x * x + y * y, 1e-24))
    ct = xp.clip(z, -1.0, 1.0)             # cos(theta)
    st = rxy                               # sin(theta) >= 0
    cp, sp = x / rxy, y / rxy              # cos/sin(phi)

    # cos(m phi), sin(m phi) by recurrence
    cos_m = [xp.ones_like(cp), cp]
    sin_m = [xp.zeros_like(sp), sp]
    for m in range(2, l_max + 1):
        cos_m.append(2 * cp * cos_m[-1] - cos_m[-2])
        sin_m.append(2 * cp * sin_m[-1] - sin_m[-2])

    # associated Legendre P_l^m(ct) with sin^m factors, standard recurrences
    p = {}
    p[(0, 0)] = xp.ones_like(ct)
    for m in range(1, l_max + 1):
        p[(m, m)] = -(2 * m - 1) * st * p[(m - 1, m - 1)]
    for m in range(0, l_max):
        p[(m + 1, m)] = (2 * m + 1) * ct * p[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            p[(l, m)] = ((2 * l - 1) * ct * p[(l - 1, m)]
                         - (l + m - 1) * p[(l - 2, m)]) / (l - m)

    out = []
    for l in range(l_max + 1):
        row = [None] * (2 * l + 1)
        for m in range(0, l + 1):
            n = math.sqrt((2 * l + 1) / (4 * math.pi)
                          * math.factorial(l - m) / math.factorial(l + m))
            if m == 0:
                row[l] = n * p[(l, 0)]
            else:
                row[l + m] = math.sqrt(2) * n * p[(l, m)] * cos_m[m]
                row[l - m] = math.sqrt(2) * n * p[(l, m)] * sin_m[m]
        out.extend(row)
    return xp.stack(out, axis=-1)


# ---------------------------------------------------------------------------
# Wigner-D via numeric solve (oracle) and J-matrix fast path
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _sample_dirs(l_max: int) -> np.ndarray:
    """Well-spread fixed sample directions (Fibonacci sphere)."""
    n = max(2 * num_coeffs(l_max), 32)
    i = np.arange(n) + 0.5
    phi = np.arccos(1 - 2 * i / n)
    theta = np.pi * (1 + 5 ** 0.5) * i
    return np.stack([np.sin(phi) * np.cos(theta),
                     np.sin(phi) * np.sin(theta),
                     np.cos(phi)], axis=-1)


@lru_cache(maxsize=None)
def _sh_pinv(l_max: int):
    """Per-l pseudo-inverse of Y^l at the sample dirs (numpy, at import)."""
    s = _sample_dirs(l_max)
    ys = real_sph_harm(l_max, s, xp=np)                     # (n_s, (L+1)^2)
    pinvs = []
    for l in range(l_max + 1):
        block = ys[:, l * l:(l + 1) ** 2]                   # (n_s, 2l+1)
        pinvs.append(np.linalg.pinv(block))                 # (2l+1, n_s)
    return pinvs


def wigner_solve(l_max: int, rot, xp=jnp):
    """Oracle Wigner blocks for rotation matrices rot: (..., 3, 3).

    Returns list per l of (..., 2l+1, 2l+1) with
    Y^l(R r) = D^l(R) @ Y^l(r).
    """
    s = xp.asarray(_sample_dirs(l_max), dtype=rot.dtype)    # (n_s, 3)
    rs = xp.einsum("...ij,nj->...ni", rot, s)               # (..., n_s, 3)
    y_rs = real_sph_harm(l_max, rs, xp=xp)                  # (..., n_s, K)
    blocks = []
    for l in range(l_max + 1):
        pinv = xp.asarray(_sh_pinv(l_max)[l], dtype=rot.dtype)
        yb = y_rs[..., l * l:(l + 1) ** 2]                  # (..., n_s, 2l+1)
        # D^l: rows = rotated SH index: Y(R s) = D Y(s) =>
        # y_rs[n, i] = sum_j D[i, j] Y[n, j]  =>  D = (pinv @ y_rs)^T
        d = xp.swapaxes(xp.einsum("jn,...ni->...ji", pinv, yb), -1, -2)
        blocks.append(d)
    return blocks


@lru_cache(maxsize=None)
def j_matrices(l_max: int) -> tuple:
    """J^l = D^l(R_swap) as numpy constants (via the numpy oracle, so
    first touch inside a jit trace stays concrete).

    R_swap is the INVOLUTIVE 180-degree rotation about (y+z)/sqrt(2)
    (y<->z, x->-x), the e3nn convention: Ry(b) = R_swap Rz(b) R_swap
    holds exactly (conjugation by an involution), hence
    D(Ry(b)) = J D(Rz(b)) J with J^2 = I.  (Ry(pi/2) does NOT satisfy
    this identity — conjugating Rz by it yields Rx, not Ry.)
    """
    r_swap = np.array([[-1.0, 0.0, 0.0],
                       [0.0, 0.0, 1.0],
                       [0.0, 1.0, 0.0]])
    blocks = wigner_solve(l_max, r_swap, xp=np)
    return tuple(np.asarray(b) for b in blocks)


def _dz_blocks(l_max: int, ang):
    """D^l(Rz(ang)) blocks; ang: (...,) -> list of (..., 2l+1, 2l+1)."""
    blocks = []
    for l in range(l_max + 1):
        dim = 2 * l + 1
        rows = []
        cos = [jnp.cos(m * ang) for m in range(l + 1)]
        sin = [jnp.sin(m * ang) for m in range(l + 1)]
        d = jnp.zeros((*ang.shape, dim, dim), ang.dtype)
        d = d.at[..., l, l].set(1.0)
        for m in range(1, l + 1):
            ip, im = l + m, l - m
            d = d.at[..., ip, ip].set(cos[m])
            d = d.at[..., im, im].set(cos[m])
            d = d.at[..., ip, im].set(-sin[m])
            d = d.at[..., im, ip].set(sin[m])
        blocks.append(d)
    return blocks


def wigner_align_z(l_max: int, dirs):
    """Wigner blocks of the rotation taking each dir to +z (fast path).

    dirs: (..., 3) unit vectors.  R = Ry(-beta) @ Rz(-alpha) with
    alpha = atan2(y, x), beta = arccos(z);  D = [J Dz(-beta) J] Dz(-alpha).
    """
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    alpha = jnp.arctan2(y, x)
    beta = jnp.arccos(jnp.clip(z, -1.0, 1.0))
    dz_a = _dz_blocks(l_max, -alpha)
    dz_b = _dz_blocks(l_max, -beta)
    js = j_matrices(l_max)
    blocks = []
    for l in range(l_max + 1):
        j = jnp.asarray(js[l], dtype=dirs.dtype)
        dy = j @ dz_b[l] @ j
        blocks.append(dy @ dz_a[l])
    return blocks


def apply_wigner(blocks, feats, *, transpose: bool = False):
    """Rotate irrep features. feats: (..., (l_max+1)^2, C)."""
    outs = []
    for l, d in enumerate(blocks):
        xl = feats[..., l * l:(l + 1) ** 2, :]
        if transpose:
            outs.append(jnp.einsum("...ji,...jc->...ic", d, xl))
        else:
            outs.append(jnp.einsum("...ij,...jc->...ic", d, xl))
    return jnp.concatenate(outs, axis=-2)


def truncated_size(l_max: int, m_max: int) -> int:
    """Number of irrep components with |m| <= m_max."""
    return sum(min(2 * l + 1, 2 * m_max + 1) for l in range(l_max + 1))


def apply_wigner_truncated(blocks, feats, m_max: int):
    """Rotate INTO the edge frame keeping only |m| <= m_max output rows.

    The eSCN SO(2) conv reads and writes only the |m| <= m_max components
    of the rotated features (everything else is zeroed), so the full
    (2l+1)x(2l+1) rotation wastes compute and bytes: for l_max=6/m_max=2
    only 29 of 49 rows are live.  Returns (..., truncated_size, C) in
    per-l blocks of min(2l+1, 2m_max+1) rows, ordered m = -m_max..m_max.
    """
    outs = []
    for l, d in enumerate(blocks):
        lo = max(0, l - m_max)
        hi = min(2 * l, l + m_max)
        xl = feats[..., l * l:(l + 1) ** 2, :]
        outs.append(jnp.einsum("...ij,...jc->...ic",
                               d[..., lo:hi + 1, :], xl))
    return jnp.concatenate(outs, axis=-2)


def apply_wigner_expand(blocks, feats_trunc, m_max: int):
    """Rotate BACK from the truncated edge frame: y = D^T y_trunc, using
    only the |m| <= m_max columns of each D^l (inverse of
    apply_wigner_truncated)."""
    outs = []
    off = 0
    for l, d in enumerate(blocks):
        lo = max(0, l - m_max)
        hi = min(2 * l, l + m_max)
        rows = hi - lo + 1
        yl = feats_trunc[..., off:off + rows, :]
        off += rows
        outs.append(jnp.einsum("...ji,...jc->...ic",
                               d[..., lo:hi + 1, :], yl))
    return jnp.concatenate(outs, axis=-2)


def truncated_index(l: int, m: int, l_max: int, m_max: int) -> int:
    """Flat index of (l, m) within the truncated layout."""
    assert abs(m) <= min(l, m_max)
    off = sum(min(2 * ll + 1, 2 * m_max + 1) for ll in range(l))
    lo = max(0, l - m_max)          # first stored row is m = lo - l
    return off + (l + m) - lo


def rotation_matrices(axis_angles):
    """Rodrigues: (..., 3) axis*angle -> (..., 3, 3). For tests."""
    theta = jnp.linalg.norm(axis_angles, axis=-1, keepdims=True)
    k = axis_angles / jnp.maximum(theta, 1e-12)
    kx, ky, kz = k[..., 0], k[..., 1], k[..., 2]
    zero = jnp.zeros_like(kx)
    kmat = jnp.stack([
        jnp.stack([zero, -kz, ky], -1),
        jnp.stack([kz, zero, -kx], -1),
        jnp.stack([-ky, kx, zero], -1)], -2)
    t = theta[..., None]
    eye = jnp.eye(3, dtype=axis_angles.dtype)
    return eye + jnp.sin(t) * kmat + (1 - jnp.cos(t)) * (kmat @ kmat)
