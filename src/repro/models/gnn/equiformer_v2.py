"""EquiformerV2-style equivariant graph attention via eSCN SO(2) convolutions.

arXiv:2306.12059.  Node features are real-SH irreps (N, (l_max+1)^2, C)
(sphere channels C for every (l, m)).  Per layer:

1. edge scores from invariant (l=0) channels + distance RBF -> per-head
   segment-softmax attention over incoming edges;
2. eSCN conv: rotate source irreps into the edge frame (Wigner align-z,
   fast J-matrix path), SO(2)-mix per |m| <= m_max with complex-pair
   weights, gate by a radial MLP, rotate back;
3. aggregate messages (attention-weighted segment-sum), per-l linear
   projection, residual;
4. equivariant LayerNorm + gated FFN (SiLU on l=0; sigmoid(l=0) gates
   scaling l>0 — the gate nonlinearity; the paper's S2 grid activation is
   noted as a simplification in DESIGN.md).

Large graphs (ogb-products: 61.8M edges x 49 irreps x 128ch) cannot
materialize per-edge messages in HBM at once: messages run in a
lax.scan over edge chunks with a carried node accumulator — the attention
denominator is computed in a cheap full-edge first pass (scores are
per-edge scalars).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.models.gnn import segment_ops as seg
from repro.models.gnn import so3
from repro.nn import core as nn
from repro.parallel.sharding import constrain

N_RBF = 8
_EDGE_CHUNK = 1 << 20            # default; override via GNNConfig.edge_chunk


def _rbf(dist, r_max: float = 5.0):
    centers = jnp.linspace(0.0, r_max, N_RBF)
    gamma = N_RBF / r_max
    return jnp.exp(-gamma * jnp.square(dist[..., None] - centers))


def _m_l_counts(l_max: int, m_max: int):
    """For each m in 0..m_max: the l values carrying that m."""
    return {m: list(range(m, l_max + 1)) for m in range(m_max + 1)}


def init(key, cfg: GNNConfig, d_in: int, n_out: int):
    c, lmax, mmax = cfg.d_hidden, cfg.l_max, cfg.m_max
    k = (lmax + 1) ** 2
    ml = _m_l_counts(lmax, mmax)
    keys = iter(jax.random.split(key, 6 + cfg.n_layers * 16))

    def dense(d1, d2):
        return nn.dense_init(next(keys), d1, d2, scale="lecun")

    params = {
        "gnn_embed": dense(d_in, c),
        "gnn_layers": [],
        "gnn_out_ln": nn.layernorm_init(c),
        "gnn_decoder": dense(c, n_out),
    }
    for _ in range(cfg.n_layers):
        lp = {
            "radial": nn.mlp_init(next(keys), N_RBF, [c],
                                  (mmax + 1) * c),
            "alpha": nn.mlp_init(next(keys), 2 * c + N_RBF, [c],
                                 cfg.n_heads),
            "so2_m0": dense(len(ml[0]) * c, len(ml[0]) * c),
            "so2_m": [],
            "proj": {"w": (jax.random.normal(next(keys), (lmax + 1, c, c))
                           * (1.0 / c ** 0.5))},
            "ln_scale": jnp.ones((lmax + 1, c)),
            "ffn_l0": nn.mlp_init(next(keys), c, [2 * c], c),
            "ffn_gate": dense(c, lmax * c),
            "ffn_mix": {"w": (jax.random.normal(next(keys), (lmax + 1, c, c))
                              * (1.0 / c ** 0.5))},
        }
        for m in range(1, mmax + 1):
            dm = len(ml[m]) * c
            lp["so2_m"].append({"wr": dense(dm, dm), "wi": dense(dm, dm)})
        params["gnn_layers"].append(lp)
    return params


def _so2_conv(lp, cfg: GNNConfig, x_rot, gates, *, truncated: bool = False):
    """SO(2) linear mix in the edge frame, truncated at m_max.

    x_rot: (E, K, C) rotated irreps — K = (l_max+1)^2 in the full layout,
    or so3.truncated_size(l_max, m_max) when ``truncated`` (only the live
    |m| <= m_max rows were rotated; see §Perf cell C).  gates:
    (E, (m_max+1), C) radial gates.  Returns same layout as input with
    |m| > m_max components zeroed (full layout only).
    """
    lmax, mmax, c = cfg.l_max, cfg.m_max, cfg.d_hidden
    ml = _m_l_counts(lmax, mmax)
    e = x_rot.shape[0]
    out = jnp.zeros_like(x_rot)

    if truncated:
        def index(l, m):
            return so3.truncated_index(l, m, lmax, mmax)
    else:
        index = so3.flat_index

    # m = 0
    idx0 = jnp.asarray([index(l, 0) for l in ml[0]])
    x0 = x_rot[:, idx0, :].reshape(e, -1)
    y0 = nn.dense_apply(lp["so2_m0"], x0).reshape(e, len(ml[0]), c)
    out = out.at[:, idx0, :].set(y0 * gates[:, 0:1, :])

    # m > 0: complex-pair mixing
    for m in range(1, mmax + 1):
        ls = ml[m]
        ip = jnp.asarray([index(l, m) for l in ls])
        im = jnp.asarray([index(l, -m) for l in ls])
        xp = x_rot[:, ip, :].reshape(e, -1)
        xm = x_rot[:, im, :].reshape(e, -1)
        wr, wi = lp["so2_m"][m - 1]["wr"], lp["so2_m"][m - 1]["wi"]
        yp = nn.dense_apply(wr, xp) - nn.dense_apply(wi, xm)
        ym = nn.dense_apply(wi, xp) + nn.dense_apply(wr, xm)
        g = gates[:, m:m + 1, :]
        out = out.at[:, ip, :].set(yp.reshape(e, len(ls), c) * g)
        out = out.at[:, im, :].set(ym.reshape(e, len(ls), c) * g)
    return out


def _layer(lp, cfg: GNNConfig, h, graph, dirs, rbf):
    """One equivariant attention block. h: (N, K, C)."""
    s, r = graph["senders"], graph["receivers"]
    n, k, c = h.shape
    heads = cfg.n_heads
    ch = c // heads

    # ---- pass 1: attention scores (cheap, full-edge) ----
    x0 = h[:, 0, :]
    sc_in = jnp.concatenate([seg.gather(x0, s), seg.gather(x0, r), rbf], -1)
    scores = nn.mlp_apply(lp["alpha"], sc_in, activation="silu")
    alpha = seg.segment_softmax(scores, r, n)            # (E, heads)
    # zero-length edges (self-loops, padded sink edges) have no direction:
    # an align-to-z frame would be arbitrary and BREAK equivariance, so
    # their conv messages are masked out (self-interaction lives in the
    # residual/FFN path instead).
    valid = (jnp.sum(dirs * dirs, axis=-1) > 0.25).astype(alpha.dtype)
    alpha = alpha * valid[:, None]

    gates_all = jax.nn.silu(
        nn.mlp_apply(lp["radial"], rbf, activation="silu")
    ).reshape(-1, cfg.m_max + 1, c)

    # ---- pass 2: eSCN conv, chunked over edges ----
    # §Perf cell C: only |m| <= m_max rotated components are live in the
    # SO(2) mix, so the rotation keeps 29/49 rows (l_max=6, m_max=2) —
    # exact rewrite, ~40% off the dominant per-edge tensor.
    def conv_chunk(sc, rc, dc, gc, ac):
        d_blocks = so3.wigner_align_z(cfg.l_max, dc)
        xs = seg.gather(h, sc)                           # (e, K, C)
        x_rot = so3.apply_wigner_truncated(d_blocks, xs, cfg.m_max)
        y_rot = _so2_conv(lp, cfg, x_rot, gc, truncated=True)
        y = so3.apply_wigner_expand(d_blocks, y_rot, cfg.m_max)
        # attention-weight per head
        y = y.reshape(*y.shape[:-1], heads, ch) * ac[:, None, :, None]
        return y.reshape(*y.shape[:-2], c), rc

    e_total = s.shape[0]
    edge_chunk = getattr(cfg, "edge_chunk", _EDGE_CHUNK) or _EDGE_CHUNK
    if e_total > edge_chunk:
        n_chunks = -(-e_total // edge_chunk)
        pad = n_chunks * edge_chunk - e_total
        # padded edges point at segment n (sliced off after scatter)
        sp = jnp.pad(s, (0, pad))
        rp = jnp.pad(r, (0, pad), constant_values=n)
        dp = jnp.pad(dirs, ((0, pad), (0, 0)), constant_values=1.0)
        gp = jnp.pad(gates_all, ((0, pad), (0, 0), (0, 0)))
        ap = jnp.pad(alpha, ((0, pad), (0, 0)))
        shp = lambda a: a.reshape(n_chunks, edge_chunk, *a.shape[1:])

        def body(acc, xs_):
            y, rc = conv_chunk(*xs_)
            return acc + seg.scatter_sum(y, rc, n + 1), None

        acc0 = jnp.zeros((n + 1, k, c), h.dtype)
        agg, _ = jax.lax.scan(
            body, acc0, (shp(sp), shp(rp), shp(dp), shp(gp), shp(ap)),
            unroll=n_chunks if cfg.unroll_scans else 1)
        agg = agg[:n]
    else:
        y, rc = conv_chunk(s, r, dirs, gates_all, alpha)
        y = constrain(y, "edges", None, None)
        agg = seg.scatter_sum(y, rc, n)

    # ---- node update ----
    agg = _per_l_mix(lp["proj"]["w"], cfg.l_max, agg)
    h = h + agg
    h = _equivariant_ln(lp["ln_scale"], cfg.l_max, h)

    # ---- gated FFN ----
    f0 = nn.mlp_apply(lp["ffn_l0"], h[:, 0, :], activation="silu")
    gate = jax.nn.sigmoid(nn.dense_apply(lp["ffn_gate"], h[:, 0, :]))
    gate = gate.reshape(n, cfg.l_max, c)
    hl = _per_l_mix(lp["ffn_mix"]["w"], cfg.l_max, h)
    upd = jnp.concatenate([f0[:, None, :], hl[:, 1:, :] * _expand_l(
        gate, cfg.l_max)], axis=1)
    h = h + upd
    h = constrain(h, "nodes", None, None)
    return h


def _expand_l(per_l, l_max: int):
    """(N, l_max, C) per-l gates -> (N, K - 1, C) broadcast over m."""
    reps = [per_l[:, l - 1:l, :].repeat(2 * l + 1, axis=1)
            for l in range(1, l_max + 1)]
    return jnp.concatenate(reps, axis=1)


def _per_l_mix(w, l_max: int, h):
    """Per-l channel mixing: w (l_max+1, C, C), h (N, K, C)."""
    outs = []
    for l in range(l_max + 1):
        xl = h[:, l * l:(l + 1) ** 2, :]
        outs.append(jnp.einsum("nmc,cd->nmd", xl, w[l]))
    return jnp.concatenate(outs, axis=1)


def _equivariant_ln(scale, l_max: int, h, eps: float = 1e-5):
    outs = []
    for l in range(l_max + 1):
        xl = h[:, l * l:(l + 1) ** 2, :]
        if l == 0:
            mu = jnp.mean(xl, axis=-1, keepdims=True)
            var = jnp.var(xl, axis=-1, keepdims=True)
            y = (xl - mu) * jax.lax.rsqrt(var + eps)
        else:
            nrm = jnp.mean(jnp.square(xl), axis=(-2, -1), keepdims=True)
            y = xl * jax.lax.rsqrt(nrm + eps)
        outs.append(y * scale[l][None, None, :])
    return jnp.concatenate(outs, axis=1)


def apply(params, cfg: GNNConfig, graph):
    """graph: x (N, F), pos (N, 3), senders/receivers (E,) -> (N, n_out)."""
    x, pos = graph["x"], graph["pos"]
    s, r = graph["senders"], graph["receivers"]
    n = x.shape[0]
    k = (cfg.l_max + 1) ** 2

    dx = seg.gather(pos, s) - seg.gather(pos, r)
    dist = jnp.linalg.norm(dx, axis=-1)
    dirs = dx / jnp.maximum(dist, 1e-9)[:, None]
    rbf = _rbf(dist)

    h0 = nn.dense_apply(params["gnn_embed"], x)          # (N, C) invariant
    h = jnp.zeros((n, k, cfg.d_hidden), h0.dtype).at[:, 0, :].set(h0)

    for lp in params["gnn_layers"]:
        h = _layer(lp, cfg, h, graph, dirs, rbf)

    inv = nn.layernorm_apply(params["gnn_out_ln"], h[:, 0, :])
    return nn.dense_apply(params["gnn_decoder"], inv)
