"""GCN (Kipf & Welling, arXiv:1609.02907) with symmetric normalization.

h' = act( D^-1/2 (A+I) D^-1/2 H W )

Implemented over the segment-op substrate.  One production detail matters
for the large full-graph cells: *aggregate after projection* — H W first
(shrinks 1433 -> 16 features for cora, 100 -> 16 for ogb-products), then the
edge gather/scatter runs on the narrow representation, cutting edge traffic
by d_in/d_hidden (~90x for cora).  The sym-norm edge weight
1/sqrt(deg_i deg_j) is computed from degrees on the fly — the adjacency is
never materialized as a matrix (the general-graph echo of the paper's
"never fetch the adjacency" strength reduction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.models.gnn import segment_ops as seg
from repro.nn import core as nn
from repro.parallel.sharding import constrain


def init(key, cfg: GNNConfig, d_in: int, n_out: int):
    dims = [d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [n_out]
    keys = jax.random.split(key, len(dims) - 1)
    layers = [nn.dense_init(k, a, b, scale="lecun")
              for k, a, b in zip(keys, dims[:-1], dims[1:])]
    return {"gnn_layers": layers}


def apply(params, cfg: GNNConfig, graph):
    """graph: dict(x (N,F), senders (E,), receivers (E,)) -> (N, n_out)."""
    x = graph["x"]
    s, r = graph["senders"], graph["receivers"]
    n = x.shape[0]
    act = nn.ACTIVATIONS[cfg.activation]

    # self-loops are modeled by adding the node's own (normalized) term.
    deg = seg.degrees(r, n) + 1.0                       # in-degree + self
    inv_sqrt = jax.lax.rsqrt(deg)
    w_edge = (jnp.take(inv_sqrt, s) * jnp.take(inv_sqrt, r))[:, None]
    self_w = (inv_sqrt * inv_sqrt)[:, None]

    h = x
    for i, lp in enumerate(params["gnn_layers"]):
        h = nn.dense_apply(lp, h)                       # project first
        h = constrain(h, "nodes", None)
        msgs = seg.gather(h, s) * w_edge.astype(h.dtype)
        msgs = constrain(msgs, "edges", None)
        agg = seg.scatter_sum(msgs, r, n) + h * self_w.astype(h.dtype)
        h = act(agg) if i < len(params["gnn_layers"]) - 1 else agg
        h = constrain(h, "nodes", None)
    return h
