"""Synthetic LM token streams with learnable bigram structure.

A random (but fixed-seed) bigram transition matrix over the vocab generates
sequences whose next-token entropy is well below log(V), so training loss
visibly drops below the uniform baseline within a few hundred steps — a
real learning signal for the end-to-end driver without external data.
"""

from __future__ import annotations

import numpy as np


def _bigram_table(vocab: int, branching: int = 32, seed: int = 99):
    rng = np.random.RandomState(seed)
    nexts = rng.randint(0, vocab, size=(vocab, branching)).astype(np.int32)
    return nexts


def make_tokens(rng: np.random.RandomState, batch: int, seq: int,
                vocab: int, branching: int = 32):
    nexts = _bigram_table(vocab, branching)
    toks = np.zeros((batch, seq), np.int32)
    toks[:, 0] = rng.randint(0, vocab, batch)
    choice = rng.randint(0, branching, size=(batch, seq))
    for t in range(1, seq):
        toks[:, t] = nexts[toks[:, t - 1], choice[:, t]]
    return toks


def lm_batches(seed: int, batch: int, seq: int, vocab: int):
    """Infinite iterator of {"tokens", "labels"} batches (shifted)."""
    rng = np.random.RandomState(seed)
    while True:
        t = make_tokens(rng, batch, seq + 1, vocab)
        yield {"tokens": t[:, :-1], "labels": t[:, 1:].copy()}
