"""Layered uniform neighbor sampler (GraphSAGE-style) for minibatch_lg.

A REAL sampler, not a stub: builds a CSR adjacency once (numpy), then per
batch samples `fanout = (15, 10)`-hop neighborhoods around seed nodes and
emits a *fixed-shape padded subgraph* so the jitted train step never
recompiles:

    nodes      : (max_nodes,) global ids (padded with -1)
    x          : (max_nodes, F) gathered features (0 for pads)
    senders/receivers : (max_edges,) LOCAL indices into `nodes`
    edge_mask  : (max_edges,) bool
    seed_mask  : (max_nodes,) bool — loss is computed on seeds only
    y          : (max_nodes,) labels (-1 for pads)

Static shapes are the TPU-native answer to data-dependent subgraph sizes —
the same "structured over irregular" trade the paper makes for its
fixed-pattern adjacency (DESIGN.md §Adaptation).
"""

from __future__ import annotations

import numpy as np


class CSRGraph:
    def __init__(self, n_nodes: int, senders: np.ndarray,
                 receivers: np.ndarray):
        # CSR over OUT-edges of each node: neighbors(n) = senders' targets.
        order = np.argsort(senders, kind="stable")
        self.dst_sorted = receivers[order].astype(np.int32)
        counts = np.bincount(senders, minlength=n_nodes)
        self.indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self.n_nodes = n_nodes

    def sample_neighbors(self, rng, nodes: np.ndarray, k: int):
        """Uniform-with-replacement k neighbors per node; isolated -> self."""
        start = self.indptr[nodes]
        deg = self.indptr[nodes + 1] - start
        pick = (rng.rand(nodes.shape[0], k) * np.maximum(deg, 1)[:, None])
        idx = start[:, None] + pick.astype(np.int64)
        nb = self.dst_sorted[np.minimum(idx, len(self.dst_sorted) - 1)]
        nb = np.where(deg[:, None] > 0, nb, nodes[:, None])   # self-loop pad
        return nb.astype(np.int32)


def sample_subgraph(csr: CSRGraph, rng, seeds: np.ndarray,
                    fanout: tuple, x: np.ndarray, y: np.ndarray,
                    max_nodes: int, max_edges: int):
    """One padded fixed-shape subgraph batch around `seeds`."""
    frontier = seeds.astype(np.int32)
    all_src, all_dst = [], []
    layers = [frontier]
    for k in fanout:
        nb = csr.sample_neighbors(rng, frontier, k)          # (n, k)
        src = nb.reshape(-1)
        dst = np.repeat(frontier, k)
        all_src.append(src)
        all_dst.append(dst)
        frontier = np.unique(src)
        layers.append(frontier)

    nodes = np.unique(np.concatenate(layers))
    src = np.concatenate(all_src)
    dst = np.concatenate(all_dst)

    # global -> local relabel
    local = {g: i for i, g in enumerate(nodes)}
    lsrc = np.fromiter((local[g] for g in src), np.int32, len(src))
    ldst = np.fromiter((local[g] for g in dst), np.int32, len(dst))

    n, e = len(nodes), len(lsrc)
    if n > max_nodes or e > max_edges:
        raise ValueError(f"subgraph ({n}, {e}) exceeds static budget "
                         f"({max_nodes}, {max_edges})")

    out = {
        "x": np.zeros((max_nodes, x.shape[1]), np.float32),
        "senders": np.zeros((max_edges,), np.int32),
        "receivers": np.full((max_edges,), max_nodes - 1, np.int32),
        "edge_mask": np.zeros((max_edges,), bool),
        "seed_mask": np.zeros((max_nodes,), bool),
        "y": np.full((max_nodes,), -1, np.int32),
        "n_nodes": np.int32(n),
    }
    out["x"][:n] = x[nodes]
    out["senders"][:e] = lsrc
    out["receivers"][:e] = ldst
    out["edge_mask"][:e] = True
    seed_local = np.fromiter((local[g] for g in seeds), np.int32, len(seeds))
    out["seed_mask"][seed_local] = True
    out["y"][:n] = y[nodes]
    return out


def static_budget(batch_nodes: int, fanout: tuple) -> tuple:
    """(max_nodes, max_edges) worst case for a fanout tree + slack."""
    nodes = batch_nodes
    total_nodes = batch_nodes
    total_edges = 0
    frontier = batch_nodes
    for k in fanout:
        total_edges += frontier * k
        frontier = frontier * k
        total_nodes += frontier
    # unique() usually shrinks this a lot; keep the worst case for safety.
    return total_nodes, total_edges


def minibatch_stream(seed: int, graph: dict, batch_nodes: int,
                     fanout: tuple, max_nodes: int | None = None,
                     max_edges: int | None = None):
    """Infinite iterator of padded subgraph batches from a full graph."""
    n = graph["x"].shape[0]
    csr = CSRGraph(n, graph["senders"], graph["receivers"])
    mn, me = static_budget(batch_nodes, fanout)
    mn, me = max_nodes or mn, max_edges or me
    rng = np.random.RandomState(seed)
    while True:
        seeds = rng.choice(n, batch_nodes, replace=False)
        yield sample_subgraph(csr, rng, seeds, fanout, graph["x"],
                              graph["y"], mn, me)
