"""Synthetic graph generators for the four GNN shape cells.

Graph dict convention (shared with every GNN model):
    x: (N, F) node features; senders/receivers: (E,) int32 edge index;
    pos: (N, 3) optional coordinates; y: labels.

Generators are numpy-only (the device never sees graph construction) and
deterministic given a seed.  The planted community structure gives GCN a
learnable signal on the full-graph cells.
"""

from __future__ import annotations

import numpy as np


def community_graph(seed: int, n_nodes: int, n_edges: int, d_feat: int,
                    n_classes: int = 7, homophily: float = 0.8):
    """Cora-like: class-conditioned features + mostly intra-class edges."""
    rng = np.random.RandomState(seed)
    y = rng.randint(0, n_classes, n_nodes).astype(np.int32)
    proto = rng.normal(0, 1, (n_classes, d_feat)).astype(np.float32)
    x = proto[y] + rng.normal(0, 2.0, (n_nodes, d_feat)).astype(np.float32)

    intra = rng.rand(n_edges) < homophily
    src = rng.randint(0, n_nodes, n_edges)
    dst = np.empty(n_edges, np.int64)
    # intra-class edges: resample dst from same-class nodes via sorted trick
    order = np.argsort(y, kind="stable")
    class_start = np.searchsorted(y[order], np.arange(n_classes))
    class_cnt = np.bincount(y, minlength=n_classes)
    same = class_start[y[src]] + (rng.rand(n_edges)
                                  * class_cnt[y[src]]).astype(np.int64)
    dst[intra] = order[same[intra]]
    dst[~intra] = rng.randint(0, n_nodes, (~intra).sum())
    return {
        "x": x,
        "senders": src.astype(np.int32),
        "receivers": dst.astype(np.int32),
        "y": y,
    }


def mesh_graph(seed: int, n_nodes: int, n_edges: int, d_feat: int):
    """Positioned point cloud with k-NN-ish local edges (meshgraphnet)."""
    rng = np.random.RandomState(seed)
    pos = rng.normal(0, 1, (n_nodes, 3)).astype(np.float32)
    x = rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    # local edges: random pairs biased to nearby indices (cheap locality)
    src = rng.randint(0, n_nodes, n_edges)
    off = rng.randint(1, max(2, n_nodes // 100), n_edges)
    dst = (src + off) % n_nodes
    # target: local smoothing field (learnable for message passing)
    y = np.tanh(pos @ rng.normal(0, 1, (3, 3))).astype(np.float32)
    return {
        "x": x, "pos": pos,
        "senders": src.astype(np.int32),
        "receivers": dst.astype(np.int32),
        "y": y,
    }


def molecule_batch(seed: int, batch: int, n_nodes: int, n_edges: int,
                   d_feat: int):
    """Batched small molecules: (B, N, F) features, (B, E) edges, per-graph y."""
    rng = np.random.RandomState(seed)
    x = rng.normal(0, 1, (batch, n_nodes, d_feat)).astype(np.float32)
    pos = rng.normal(0, 1, (batch, n_nodes, 3)).astype(np.float32)
    senders = rng.randint(0, n_nodes, (batch, n_edges)).astype(np.int32)
    receivers = rng.randint(0, n_nodes, (batch, n_edges)).astype(np.int32)
    y = (x.mean((1, 2)) > 0).astype(np.int32)      # planted global label
    return {"x": x, "pos": pos, "senders": senders, "receivers": receivers,
            "y": y}
