"""Synthetic jet dataset generator for JEDI-net training.

The real HLS4ML LHC jet datasets (Zenodo 3601436 / 3601443) are not
available offline, so this module generates a *structured* synthetic
surrogate with the same tensor layout ((N_o particles, 16 features),
5 classes) and a planted physics-flavoured rule, so that training runs
show real learning curves and the co-design accuracy proxy can be
calibrated against actually-trained models:

Each class c gets a characteristic subjet multiplicity and angular spread;
particles are drawn as (pT, eta, phi)-like triples with class-dependent
clustering, then embedded into 16 features via a fixed random linear map +
nonlinearity, mimicking the engineered-feature redundancy of the real
dataset.  Bayes accuracy is tunable via `noise`; at the default ~0.25 a
JEDI-net reaches high accuracy while a linear model cannot.
"""

from __future__ import annotations

import numpy as np


N_CLASSES = 5


def make_jets(rng: np.random.RandomState, n: int, n_particles: int,
              n_features: int = 16, noise: float = 0.25):
    """Returns (x (n, N_o, P) float32, y (n,) int32)."""
    y = rng.randint(0, N_CLASSES, size=n).astype(np.int32)

    # class-dependent generative parameters
    n_subjets = 1 + (y % 3)                       # 1..3 clusters
    spread = 0.1 + 0.15 * (y % 2)                 # angular spread
    softness = 0.5 + 0.25 * (y // 2)              # pT falloff

    x3 = np.zeros((n, n_particles, 3), np.float32)
    for i in range(n):
        k = n_subjets[i]
        centers = rng.normal(0, 1.0, size=(k, 2))
        assign = rng.randint(0, k, size=n_particles)
        ang = centers[assign] + rng.normal(0, spread[i], (n_particles, 2))
        # pT: falling spectrum, leading particles first
        pt = rng.exponential(softness[i], n_particles).astype(np.float32)
        pt = np.sort(pt)[::-1]
        x3[i, :, 0] = np.log1p(pt)            # compress the pT spectrum
        x3[i, :, 1:] = ang
    # embed 3 -> n_features with a FIXED random map (shared across calls)
    emb_rng = np.random.RandomState(1234)
    w1 = emb_rng.normal(0, 1.0, (3, n_features)).astype(np.float32)
    w2 = emb_rng.normal(0, 0.5, (3, n_features)).astype(np.float32)
    x = np.tanh(x3 @ w1) + x3 @ w2
    x += rng.normal(0, noise, x.shape).astype(np.float32)
    # fixed global standardization keeps inputs O(1) for any noise level
    x = (x - x.mean(axis=(0, 1), keepdims=True)) / (
        x.std(axis=(0, 1), keepdims=True) + 1e-6)
    return x.astype(np.float32), y


def jet_batches(seed: int, batch: int, n_particles: int,
                n_features: int = 16, noise: float = 0.25):
    """Infinite iterator of {"x", "y"} batches."""
    rng = np.random.RandomState(seed)
    while True:
        x, y = make_jets(rng, batch, n_particles, n_features, noise)
        yield {"x": x, "y": y}


# --- large-graph regime: track-level events ---------------------------------

#: Tracks per event in the large-graph configs — the regime real-time
#: graph building on FPGAs targets (Neu et al., arXiv:2307.07289:
#: O(100) tracks/event at 40 MHz), where the UNTILED whole-network
#: kernel's (N_o, N_o, H1) grid no longer fits VMEM at any batch tile
#: and only the sender-tiled kernel applies.
TRACKS_N = 128


def make_tracks(rng: np.random.RandomState, n: int,
                n_tracks: int = TRACKS_N, n_features: int = 16,
                noise: float = 0.25):
    """Synthetic TRACK-level events: (x (n, n_tracks, P) float32, y (n,)).

    Where :func:`make_jets` plants calorimeter-style (pT, eta, phi)
    clusters, this generator mimics an inner-tracker readout: each class
    plants a characteristic number of displaced vertices, and every
    track carries 5 helix-flavoured raw features — (log pT, eta, phi,
    d0, z0) — with d0/z0 drawn around its vertex, then embedded into
    ``n_features`` via the same fixed random map + nonlinearity trick
    so the tensor layout matches the jet datasets exactly.  Same label
    space (:data:`N_CLASSES`) so the full JEDI-net stack runs unchanged
    at N_o = ``n_tracks``.
    """
    y = rng.randint(0, N_CLASSES, size=n).astype(np.int32)

    # class-dependent generative parameters
    n_vertices = 1 + (y % 3)                      # prompt + displaced
    displacement = 0.05 + 0.20 * (y % 2)          # d0/z0 scale per class
    softness = 0.5 + 0.25 * (y // 2)              # pT falloff

    x5 = np.zeros((n, n_tracks, 5), np.float32)
    for i in range(n):
        k = n_vertices[i]
        vtx = rng.normal(0, displacement[i], size=(k, 2))   # (d0, z0) centers
        dirs = rng.normal(0, 1.0, size=(k, 2))              # (eta, phi) axes
        assign = rng.randint(0, k, size=n_tracks)
        pt = rng.exponential(softness[i], n_tracks).astype(np.float32)
        pt = np.sort(pt)[::-1]
        x5[i, :, 0] = np.log1p(pt)
        x5[i, :, 1:3] = dirs[assign] + rng.normal(0, 0.2, (n_tracks, 2))
        x5[i, :, 3:5] = vtx[assign] + rng.normal(
            0, 0.02, (n_tracks, 2))
    emb_rng = np.random.RandomState(4321)
    w1 = emb_rng.normal(0, 1.0, (5, n_features)).astype(np.float32)
    w2 = emb_rng.normal(0, 0.5, (5, n_features)).astype(np.float32)
    x = np.tanh(x5 @ w1) + x5 @ w2
    x += rng.normal(0, noise, x.shape).astype(np.float32)
    x = (x - x.mean(axis=(0, 1), keepdims=True)) / (
        x.std(axis=(0, 1), keepdims=True) + 1e-6)
    return x.astype(np.float32), y


def track_batches(seed: int, batch: int, n_tracks: int = TRACKS_N,
                  n_features: int = 16, noise: float = 0.25):
    """Infinite iterator of {"x", "y"} track-level batches."""
    rng = np.random.RandomState(seed)
    while True:
        x, y = make_tracks(rng, batch, n_tracks, n_features, noise)
        yield {"x": x, "y": y}
