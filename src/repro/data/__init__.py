from repro.data import jets, lm_data, graphs, neighbor_sampler, recsys_data

__all__ = ["jets", "lm_data", "graphs", "neighbor_sampler", "recsys_data"]
