"""Synthetic CTR data with a planted factorized rule for FM training."""

from __future__ import annotations

import numpy as np


def ctr_batches(seed: int, batch: int, vocab_sizes: tuple,
                embed_dim: int = 8):
    """Infinite {"ids" (B, F), "y" (B,)} stream; labels follow a hidden FM."""
    rng = np.random.RandomState(seed)
    f = len(vocab_sizes)
    # hidden true factors (hashed per field to keep memory tiny)
    h_dim = 64
    field_emb = rng.normal(0, 0.5, (f, h_dim, embed_dim)).astype(np.float32)
    while True:
        ids = np.stack([rng.randint(0, s, batch) for s in vocab_sizes], 1)
        v = field_emb[np.arange(f)[None, :], ids % h_dim]      # (B, F, K)
        sv = v.sum(1)
        score = 0.5 * ((sv ** 2).sum(-1) - (v ** 2).sum(1).sum(-1))
        p = 1.0 / (1.0 + np.exp(-score))
        y = (rng.rand(batch) < p).astype(np.int32)
        yield {"ids": ids.astype(np.int32), "y": y}
