"""Learning-rate schedules (pure functions step -> lr).

Includes WSD (warmup-stable-decay) from MiniCPM [arXiv:2404.06395] — the
training-side feature of the assigned minicpm-2b arch — plus the standard
warmup-cosine and constant schedules.
"""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def f(step):
        return jnp.asarray(lr, jnp.float32)
    return f


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)
    return f


def wsd(lr: float, warmup_steps: int, total_steps: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> stable plateau -> short exponential-ish decay (MiniCPM).

    decay starts at (1 - decay_frac) * total_steps; within the decay phase
    lr falls geometrically to final_frac * lr.
    """
    decay_start = int(total_steps * (1.0 - decay_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - decay_start) / jnp.maximum(total_steps - decay_start, 1)
        t = jnp.clip(t, 0.0, 1.0)
        decay = lr * jnp.power(final_frac, t)
        stable = jnp.asarray(lr, jnp.float32)
        out = jnp.where(step < warmup_steps, warm,
                        jnp.where(step < decay_start, stable, decay))
        return out
    return f


SCHEDULES = {
    "constant": constant,
    "warmup_cosine": warmup_cosine,
    "wsd": wsd,
}
