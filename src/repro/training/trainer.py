"""Train-step builder: grad accumulation, mixed precision, pjit-ready.

``make_train_step(loss_fn, optimizer)`` returns a pure
``step(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
in/out shardings.  Featured:

* **Gradient accumulation** — ``grad_accum > 1`` splits the batch's leading
  axis into microbatches and lax.scan's over them, so the train_4k cells
  can trade activation memory for steps without touching model code.
* **Mixed precision** — loss_fn handles bf16 compute internally; grads are
  accumulated in fp32.
* **Data parallelism by sharding** — the batch axis is sharded over
  (pod, data); XLA inserts the gradient all-reduce automatically from the
  sharding propagation, overlapping it with the backward pass (the
  standard XLA latency-hiding scheduler behaviour) — no explicit pmean.

The trainer state is a plain dict so the checkpoint module can shard/save
it without special cases.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.training.optimizer import Optimizer


def init_state(key, init_params_fn: Callable, optimizer: Optimizer):
    params = init_params_fn(key)
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(loss_fn: Callable, optimizer: Optimizer, *,
                    grad_accum: int = 1):
    """loss_fn(params, batch) -> (scalar loss, metrics dict)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        return loss, metrics, grads

    def step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def micro(b):
                return jax.tree_util.tree_map(
                    lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                        *x.shape[1:]), b)

            def body(carry, mb):
                acc, loss_acc = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), metrics

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), micro(batch))
            grads = jax.tree_util.tree_map(
                lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            metrics = jax.tree_util.tree_map(
                lambda m: jnp.mean(m), metrics)

        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state["opt"], params, state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, out_metrics

    return step
