"""Optimizers as (init, update) pure-function pairs over dict pytrees.

Built from scratch (no optax per the project brief).  Three optimizers:

* ``sgd``       — momentum SGD (GNN full-graph baselines).
* ``adamw``     — decoupled weight decay Adam; fp32 m/v states.
* ``adafactor`` — factored second moments (row/col running means) for
  matrix-shaped leaves, full second moment for vectors/scalars.  This is
  what makes the arctic-480b train cell *fit*: AdamW's fp32 m/v would need
  2 x 4 bytes x 479B params = 3.8 TB of optimizer state; Adafactor's
  factored accumulators are O(rows + cols) per matrix (~MB-scale), the
  standard memory-side distributed-training trade (Shazeer & Stern,
  arXiv:1804.04235).

All updates take grads in any float dtype, compute in fp32, and return
param deltas applied in the params' own dtype.  Gradient clipping by global
norm is part of ``update`` so the clip happens AFTER cross-data-parallel
gradient averaging (the psum lives in the train step, not here).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.common.tree import global_norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable    # (grads, state, params, step) -> (new_params, new_state, metrics)
    name: str = ""


def _clip_tree(grads, clip_norm):
    gn = global_norm(grads)
    if clip_norm is None:
        scale = jnp.asarray(1.0, jnp.float32)
    else:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-12))
    g32 = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads)
    return g32, gn


def sgd(lr_fn, momentum: float = 0.9, clip_norm: Optional[float] = 1.0,
        weight_decay: float = 0.0):
    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        g32, gn = _clip_tree(grads, clip_norm)
        lr = lr_fn(step)
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g, state["mu"], g32)
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32)
                          - lr * (m + weight_decay * p.astype(jnp.float32))
                          ).astype(p.dtype),
            params, mu)
        return new_params, {"mu": mu}, {"grad_norm": gn, "lr": lr}

    return Optimizer(init, update, "sgd")


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01, clip_norm: Optional[float] = 1.0):
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        g32, gn = _clip_tree(grads, clip_norm)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - jnp.power(b1, t)
        bc2 = 1.0 - jnp.power(b2, t)
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], g32)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, {"m": m, "v": v}, {"grad_norm": gn, "lr": lr}

    return Optimizer(init, update, "adamw")


def adafactor(lr_fn, decay: float = 0.8, eps: float = 1e-30,
              clip_norm: Optional[float] = 1.0, weight_decay: float = 0.0,
              min_dim_factored: int = 128):
    """Factored Adafactor (no momentum), per Shazeer & Stern.

    Matrix leaves with both trailing dims >= min_dim_factored get factored
    row/col accumulators; everything else keeps a full second moment.
    Leading axes (e.g. scan-stacked layer axis, MoE expert axis) are kept in
    the factored shapes.
    """

    def _factored(p) -> bool:
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_factored
                and p.shape[-2] >= min_dim_factored)

    def init(params):
        def st(p):
            if _factored(p):
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),        # row
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"acc": jax.tree_util.tree_map(
            st, params, is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params, step):
        g32, gn = _clip_tree(grads, clip_norm)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        # increasing decay schedule: beta2_t = 1 - t^-decay
        b2t = 1.0 - jnp.power(t, -decay)

        def upd(p, g, acc):
            g2 = jnp.square(g) + eps
            if "r" in acc:
                r = b2t * acc["r"] + (1 - b2t) * jnp.mean(g2, axis=-1)
                c = b2t * acc["c"] + (1 - b2t) * jnp.mean(g2, axis=-2)
                # v_hat = outer(r, c) / mean(r)
                rmean = jnp.mean(r, axis=-1, keepdims=True)
                vhat = (r / jnp.maximum(rmean, eps))[..., None] * c[..., None, :]
                new_acc = {"r": r, "c": c}
            else:
                vhat = b2t * acc["v"] + (1 - b2t) * g2
                new_acc = {"v": vhat}
            u = g * jax.lax.rsqrt(vhat + eps)
            # update clipping (RMS <= 1), per the paper
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms)
            newp = (p.astype(jnp.float32)
                    - lr * (u + weight_decay * p.astype(jnp.float32)))
            return newp.astype(p.dtype), new_acc

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(g32)
        flat_a = treedef.flatten_up_to(state["acc"])
        out = [upd(p, g, a) for p, g, a in zip(flat_p, flat_g, flat_a)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_acc = treedef.unflatten([o[1] for o in out])
        return new_params, {"acc": new_acc}, {"grad_norm": gn, "lr": lr}

    return Optimizer(init, update, "adafactor")


OPTIMIZERS = {"sgd": sgd, "adamw": adamw, "adafactor": adafactor}


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    return OPTIMIZERS[name](lr_fn, **kw)
