from repro.training import schedule, optimizer, trainer, grad_compression
from repro.training.optimizer import make_optimizer, Optimizer
from repro.training.schedule import SCHEDULES
from repro.training.trainer import init_state, make_train_step

__all__ = [
    "schedule", "optimizer", "trainer", "grad_compression",
    "make_optimizer", "Optimizer", "SCHEDULES", "init_state",
    "make_train_step",
]
