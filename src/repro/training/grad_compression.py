"""Error-feedback int8 gradient compression for the data-parallel all-reduce.

At 1000+-node scale the DP gradient all-reduce is the dominant collective;
compressing grads to int8 with per-leaf scales cuts its bytes 4x (fp32) /
2x (bf16).  Naive quantization biases training; error feedback (Seide et
al. 2014; Karimireddy et al. 2019, arXiv:1901.09847) carries the
quantization residual into the next step, which provably preserves SGD
convergence for smooth objectives.

The compression is applied INSIDE shard_map around the psum: each shard
quantizes (grad + residual), all-reduces the int8 payload as int32 partial
sums (bit-exact accumulation — no float re-quantization error across the
ring), dequantizes, and keeps the local residual.

On this CPU container the code paths are exercised by tests over a fake
multi-device mesh; the collective itself is `jax.lax.psum`, identical on
real ICI.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, *, bits: int = 8):
    """Symmetric per-tensor int quantization. Returns (q int8/int16, scale)."""
    assert bits in (8, 16)
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-20) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    dt = jnp.int8 if bits == 8 else jnp.int16
    return q.astype(dt), scale.astype(jnp.float32)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compressed_psum(grads, residuals, axis_name: str, *, bits: int = 8):
    """Error-feedback compressed psum over `axis_name`.

    grads/residuals: pytrees of fp32 leaves (per-shard gradients).
    Returns (mean_grads, new_residuals).  Must be called inside shard_map /
    pmap with `axis_name` bound.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        corrected = g + r
        q, scale = quantize(corrected, bits=bits)
        # int32 ring accumulation is exact; scales are averaged separately
        # (per-shard scale variation is second-order w/ error feedback).
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(scale, axis_name)
        mean = qsum.astype(jnp.float32) * (ssum / n) / n
        new_r = corrected - dequantize(q, scale)      # local residual
        return mean, new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
