"""Checkpointing: sharded, async, atomic, elastic-restore.

Design (mirrors production TPU trainers, scaled to this container):

* **Layout** — one directory per step: ``<dir>/step_<n>/`` holding a
  ``manifest.json`` (pytree structure, shapes, dtypes) and one ``.npy``
  per leaf (array payload).  Leaves are written *unsharded* (device_get
  of the addressable array); on a real multi-host pod each host writes
  only its addressable shards and the manifest carries the global shape —
  the restore path below is already global-shape based so it works for
  both.
* **Atomicity** — writes go to ``step_<n>.tmp`` then ``os.rename`` (POSIX
  atomic), so a preempted save never corrupts the latest checkpoint; a
  partial tmp dir is garbage-collected on the next save.
* **Async** — ``save_async`` snapshots to host memory synchronously (cheap)
  and runs the file I/O in a daemon thread, so training resumes
  immediately; ``wait()`` joins before the next save to bound memory.
* **Elastic restore** — ``restore`` takes an optional (mesh, shardings)
  pair and ``jax.device_put``s each leaf onto the *current* mesh, which can
  be a different size/shape than the one that saved (e.g. after losing a
  pod): checkpoints are the unit of elasticity.
* **Retention** — ``keep_last`` prunes old steps after a successful save.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.common.tree import path_map


def _leaf_paths(tree) -> dict:
    """{path_string: leaf} for every array leaf."""
    out = {}
    path_map(lambda p, l: out.__setitem__(p, l) or l, tree)
    return out


def _unflatten(manifest: dict, payload: dict):
    """Rebuild the pytree from manifest structure + loaded arrays."""

    def build(node):
        if isinstance(node, dict) and node.get("__leaf__"):
            return payload[node["path"]]
        if isinstance(node, dict):
            return {k: build(v) for k, v in node.items()}
        if isinstance(node, list):
            return [build(v) for v in node]
        return node

    return build(manifest["tree"])


def _tree_manifest(tree, prefix=""):
    if isinstance(tree, dict):
        return {k: _tree_manifest(v, f"{prefix}{k}/") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_tree_manifest(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
    path = prefix[:-1]
    return {"__leaf__": True, "path": path}


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # --- save ---------------------------------------------------------------

    def _write(self, step: int, host_tree: Any):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _leaf_paths(host_tree)
        manifest = {"step": step, "tree": _tree_manifest(host_tree),
                    "leaves": {}}
        for path, arr in leaves.items():
            arr = np.asarray(arr)
            fname = path.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][path] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()

    def _prune(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, step: int, tree: Any):
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                      tree)
        self._write(step, host)

    def save_async(self, step: int, tree: Any):
        self.wait()
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                      tree)
        # NON-daemon: on a crash (injected failure, unhandled exception) the
        # interpreter joins this thread at exit, so an in-flight save always
        # finalizes its atomic rename instead of dying as a stale .tmp —
        # that durability is what crash-restart recovery restores from.
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=False)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --- restore ------------------------------------------------------------

    def all_steps(self) -> list:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, shardings=None):
        """Load a checkpoint; optionally place leaves per a shardings pytree
        (elastic restore onto the current mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        payload = {}
        for path, meta in manifest["leaves"].items():
            payload[path] = np.load(os.path.join(d, meta["file"]))
        tree = _unflatten(manifest, payload)
        if shardings is not None:
            flat_t, treedef = jax.tree_util.tree_flatten(tree)
            flat_s = treedef.flatten_up_to(shardings)
            tree = treedef.unflatten([
                jax.device_put(t, s) if s is not None else jax.device_put(t)
                for t, s in zip(flat_t, flat_s)])
        return tree, step
