from repro.checkpoint.checkpoint import CheckpointManager

__all__ = ["CheckpointManager"]
