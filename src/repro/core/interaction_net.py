"""JEDI-net interaction network (the paper's end-to-end application).

Three functionally identical forward paths are provided:

* ``forward_dense``   — the paper-[5] baseline: explicit dense MMMs with the
  one-hot relation matrices Rr / Rs.  Kept as the faithful *unoptimized*
  reference (and the oracle for tests / op-count benchmarks).
* ``forward_sr``      — the paper's contribution mapped to TPU: strength
  reduction (Sec 3.1), edge-major a.k.a. "column-major" layout (Sec 3.2) and
  outer-product-style aggregation as a reshape+reduce (Sec 3.3).  All three
  MMMs are eliminated; only the MLP GEMMs remain, exactly as on the FPGA
  where only the MLPs consume DSPs.
* ``forward_fused``   — the Sec 3.5 "divide, conquer, fuse" step: a Pallas
  kernel fuses B-construction + f_R + the incoming-edge reduction in VMEM so
  the (N_E x D_e) edge-message matrix E never round-trips through HBM.
  This is the TPU analogue of removing the ping-pong buffers between
  coarse-grained pipeline stages.
* ``forward_fused_full`` — fusion extended end-to-end: ONE Pallas kernel
  computes x -> logits (f_R grid, aggregation, f_O, node-sum, phi_O) per
  batch tile, so the only HBM traffic is weights + x in and logits out —
  the TPU analogue of the paper's fully-fused layer-wise architecture
  where every stage hand-off is an on-chip stream.

Layout convention: inputs are (batch, N_o, P) node-major, i.e. each node's
feature vector is contiguous (minor-most) — the TPU translation of the
paper's column-major order.  The original (P, N_o) single-jet layout of [5]
is exposed through the dense baseline for fidelity.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import adjacency, paths
from repro.nn import core as nn


@dataclasses.dataclass(frozen=True)
class JediNetConfig:
    """JEDI-net model hyper-parameters (Table 2 of the paper).

    ``fr_hidden`` etc. follow the paper's (NL, S) notation: NL hidden layers,
    each of size S.  ``d_e = 8`` is backed out of Fig. 8 (6,960 = D_e * N_E
    remaining adds for the 30p model => D_e = 8).
    """

    n_objects: int = 30          # N_o: particles per jet (30p / 50p datasets)
    n_features: int = 16         # P: features per particle
    d_e: int = 8                 # f_R output (edge hidden features)
    d_o: int = 24                # f_O output (per-node post-interaction repr)
    n_targets: int = 5           # jet classes: g, q, W, Z, t
    fr_hidden: Sequence[int] = (20, 20, 20)
    fo_hidden: Sequence[int] = (20, 20, 20)
    phi_hidden: Sequence[int] = (20, 20, 20)
    activation: str = "relu"
    compute_dtype: str = "float32"

    @property
    def n_edges(self) -> int:
        return self.n_objects * (self.n_objects - 1)

    def with_(self, **kw) -> "JediNetConfig":
        return dataclasses.replace(self, **kw)


def init(key, cfg: JediNetConfig, *, scale: str = "fan_in"):
    """``scale``: variance-scaling rule (see nn.dense_init).  "lecun" keeps
    activations O(1) through the N_o-way message sums of an untrained net —
    useful for numerics tests where He init would blow logits up ~N_o-fold.
    """
    kfr, kfo, kphi = jax.random.split(key, 3)
    return {
        "fr": nn.mlp_init(kfr, 2 * cfg.n_features, cfg.fr_hidden, cfg.d_e,
                          scale=scale),
        "fo": nn.mlp_init(kfo, cfg.n_features + cfg.d_e, cfg.fo_hidden,
                          cfg.d_o, scale=scale),
        "phi": nn.mlp_init(kphi, cfg.d_o, cfg.phi_hidden, cfg.n_targets,
                           scale=scale),
    }


def _cdt(cfg: JediNetConfig):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Paper-[5] baseline: explicit dense MMMs with Rr / Rs.
# ---------------------------------------------------------------------------

def forward_dense(params, cfg: JediNetConfig, x):
    """Baseline JEDI-net with explicit adjacency MMMs.

    x: (batch, N_o, P).  Internally transposed to the paper's (P, N_o) layout
    so MMM1/2/3 appear exactly as in [5]: B1 = I@Rr, B2 = I@Rs, Ebar = E@Rr^T.
    """
    cdt = _cdt(cfg)
    rr_np, rs_np = adjacency.dense_relation_matrices(cfg.n_objects)
    rr = jnp.asarray(rr_np, dtype=cdt)
    rs = jnp.asarray(rs_np, dtype=cdt)

    i_mat = jnp.swapaxes(x.astype(cdt), -1, -2)            # (B, P, N_o)
    b1 = i_mat @ rr                                        # MMM1: (B, P, N_E)
    b2 = i_mat @ rs                                        # MMM2: (B, P, N_E)
    b = jnp.concatenate([b1, b2], axis=-2)                 # (B, 2P, N_E)

    # f_R applied per column of B -> transpose to edge-major for the GEMM.
    b_cols = jnp.swapaxes(b, -1, -2)                       # (B, N_E, 2P)
    e_cols = nn.mlp_apply(params["fr"], b_cols, activation=cfg.activation,
                          compute_dtype=cdt)               # (B, N_E, D_e)
    e_mat = jnp.swapaxes(e_cols, -1, -2)                   # (B, D_e, N_E)

    ebar = e_mat @ rr.T                                    # MMM3: (B, D_e, N_o)

    c = jnp.concatenate([i_mat, ebar], axis=-2)            # (B, P+D_e, N_o)
    c_cols = jnp.swapaxes(c, -1, -2)                       # (B, N_o, P+D_e)
    o = nn.mlp_apply(params["fo"], c_cols, activation=cfg.activation,
                     compute_dtype=cdt)                    # (B, N_o, D_o)
    o_sum = jnp.sum(o, axis=-2)                            # (B, D_o)
    logits = nn.mlp_apply(params["phi"], o_sum, activation=cfg.activation,
                          compute_dtype=cdt)               # (B, n_targets)
    return logits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Strength-reduced, edge-major path (the paper's technique on TPU).
# ---------------------------------------------------------------------------

def build_b_matrix(cfg: JediNetConfig, x):
    """Strength-reduced MMM1/MMM2: build B (B, N_E, 2P) with zero FLOPs.

    B1 (receiver features) is a broadcast over the k axis; B2 (sender
    features) is one static gather whose index map is a compile-time
    constant — the paper's Algorithm 1.
    """
    n_o, p = cfg.n_objects, cfg.n_features
    send_idx = jnp.asarray(adjacency.sender_index_matrix(n_o))   # (N_o, N_o-1)
    # B1: receiver i's features, repeated for each of its N_o-1 incoming edges.
    b1 = jnp.broadcast_to(x[..., :, None, :], (*x.shape[:-2], n_o, n_o - 1, p))
    # B2: sender features via static gather (XLA folds the index constant).
    b2 = jnp.take(x, send_idx.reshape(-1), axis=-2)
    b2 = b2.reshape(*x.shape[:-2], n_o, n_o - 1, p)
    b = jnp.concatenate([b1, b2], axis=-1)                       # (..., N_o, N_o-1, 2P)
    return b.reshape(*x.shape[:-2], cfg.n_edges, 2 * p)


def aggregate_incoming(cfg: JediNetConfig, e_cols):
    """Strength-reduced MMM3: Ebar = E @ Rr^T as a reshape + sum over k.

    e_cols: (..., N_E, D_e) edge-major.  Receiver-major edge ordering makes
    the incoming edges of node i contiguous, so the one-hot MMM collapses to
    a contraction over a length-(N_o-1) axis: D_e*N_E adds, zero mults —
    matching the paper's 3.3%-of-additions figure.
    """
    n_o = cfg.n_objects
    e_r = e_cols.reshape(*e_cols.shape[:-2], n_o, n_o - 1, e_cols.shape[-1])
    return jnp.sum(e_r, axis=-2)                                  # (..., N_o, D_e)


def forward_sr(params, cfg: JediNetConfig, x, *, return_intermediates: bool = False):
    """Strength-reduced JEDI-net forward. x: (batch, N_o, P)."""
    cdt = _cdt(cfg)
    x = x.astype(cdt)
    b = build_b_matrix(cfg, x)                                    # (B, N_E, 2P)
    e_cols = nn.mlp_apply(params["fr"], b, activation=cfg.activation,
                          compute_dtype=cdt)                      # (B, N_E, D_e)
    ebar = aggregate_incoming(cfg, e_cols)                        # (B, N_o, D_e)
    c = jnp.concatenate([x, ebar], axis=-1)                       # (B, N_o, P+D_e)
    o = nn.mlp_apply(params["fo"], c, activation=cfg.activation,
                     compute_dtype=cdt)                           # (B, N_o, D_o)
    o_sum = jnp.sum(o, axis=-2)
    logits = nn.mlp_apply(params["phi"], o_sum, activation=cfg.activation,
                          compute_dtype=cdt)
    logits = logits.astype(jnp.float32)
    if return_intermediates:
        return logits, {"b": b, "e": e_cols, "ebar": ebar, "c": c, "o": o}
    return logits


# ---------------------------------------------------------------------------
# Fused path: Pallas kernel for B-construct + f_R + aggregate (Sec 3.5).
# ---------------------------------------------------------------------------

def forward_fused(params, cfg: JediNetConfig, x, *, interpret: bool = False):
    """JEDI-net forward using the fused Pallas edge kernel.

    The kernel computes Ebar directly from x without materializing B or E in
    HBM — the Sec 3.5 sub-layer fusion.  f_O / phi_O (the paper's DP_tail)
    remain in XLA, which fuses these small GEMMs well.
    """
    from repro.kernels.fused_jedinet import ops as fused_ops

    cdt = _cdt(cfg)
    x = x.astype(cdt)
    ebar = fused_ops.fused_edge_block(params["fr"], cfg, x, interpret=interpret)
    c = jnp.concatenate([x, ebar.astype(cdt)], axis=-1)
    o = nn.mlp_apply(params["fo"], c, activation=cfg.activation, compute_dtype=cdt)
    o_sum = jnp.sum(o, axis=-2)
    logits = nn.mlp_apply(params["phi"], o_sum, activation=cfg.activation,
                          compute_dtype=cdt)
    return logits.astype(jnp.float32)


def forward_fused_full(params, cfg: JediNetConfig, x, *,
                       interpret: bool = False):
    """JEDI-net forward as ONE whole-network Pallas kernel (x -> logits).

    Extends the Sec 3.5 fusion to every sub-layer: bilinear-split f_R,
    dense-grid aggregation, f_O, the node-sum and phi_O all execute in a
    single kernel per batch tile, so no intermediate (B, E, Ebar, C, O)
    ever touches HBM — only weights + x in, logits out.  The MXU compute
    dtype follows ``cfg.compute_dtype`` with fp32 accumulation (the
    precision/latency co-design knob).  See kernels/fused_jedinet/
    full_kernel.py and EXPERIMENTS.md §Perf.
    """
    from repro.kernels.fused_jedinet import ops as fused_ops

    return fused_ops.fused_forward_full(params, cfg, x, interpret=interpret)


# ---------------------------------------------------------------------------
# Beyond-paper optimized path (pure XLA; see EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

def forward_sr_split(params, cfg: JediNetConfig, x, *, grid: bool = True):
    """Strength reduction + bilinear first-layer split (+ dense grid).

    Two optimizations beyond the paper (same ones as the Pallas kernel,
    expressed in XLA so the dry-run/roofline sees them):

    * f_R's first layer splits over the [x_r ‖ x_s] concatenation, so the
      two projections run once per NODE (N_o rows) instead of once per
      EDGE (N_o(N_o-1) rows) — and the B matrix (N_E x 2P) is never
      materialized.
    * ``grid=True``: compute the full N_o x N_o interaction grid and
      subtract the self-edge diagonal after aggregation — regular access,
      no gather, ~1/(N_o-1) extra compute.  ``grid=False`` keeps the
      paper-style static gather of the (N_o, N_o-1) sender table.
    """
    cdt = _cdt(cfg)
    x = x.astype(cdt)
    act = nn.ACTIVATIONS[cfg.activation]
    layers = params["fr"]["layers"]
    w1 = layers[0]["w"].astype(cdt)
    b1 = layers[0]["b"].astype(cdt)
    p = cfg.n_features
    u_r = x @ w1[:p]                                       # (B, N_o, H1)
    u_s = x @ w1[p:]                                       # (B, N_o, H1)

    if grid:
        h = u_r[:, :, None, :] + u_s[:, None, :, :] + b1   # (B, N_o, N_o, H1)
    else:
        send_idx = jnp.asarray(adjacency.sender_index_matrix(cfg.n_objects))
        h = u_r[:, :, None, :] + u_s[:, send_idx, :] + b1  # (B, N_o, N_o-1, H1)
    if len(layers) > 1:
        h = act(h)
    for i, lp in enumerate(layers[1:]):
        h = h @ lp["w"].astype(cdt) + lp["b"].astype(cdt)
        if i < len(layers) - 2:
            h = act(h)

    if grid:
        total = jnp.sum(h, axis=2)                         # (B, N_o, D_e)
        diag = jnp.einsum("brsd,rs->brd", h,
                          jnp.eye(cfg.n_objects, dtype=h.dtype))
        ebar = total - diag
    else:
        ebar = jnp.sum(h, axis=2)

    c = jnp.concatenate([x, ebar.astype(cdt)], axis=-1)
    o = nn.mlp_apply(params["fo"], c, activation=cfg.activation,
                     compute_dtype=cdt)
    o_sum = jnp.sum(o, axis=-2)
    logits = nn.mlp_apply(params["phi"], o_sum, activation=cfg.activation,
                          compute_dtype=cdt)
    return logits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Path registration: one PathSpec per forward path (see core/paths.py).
# Every consumer (serving engine, batcher, CLI, benchmarks, CI gate,
# numerics tests) discovers these through the registry.
# ---------------------------------------------------------------------------

def _fused_residency(cfg, params, batch, **kw):
    from repro.kernels.fused_jedinet.autotune import modeled_residency_edge
    return modeled_residency_edge(cfg, params, batch, **kw)


def _fused_full_residency(cfg, params, batch, **kw):
    from repro.kernels.fused_jedinet.autotune import modeled_residency
    return modeled_residency(cfg, params, batch, **kw)


paths.register(paths.PathSpec(
    name="dense", forward=forward_dense, ref=forward_sr,
    fused_level="none", tolerance=2e-4,
    complexity="O(N^2)", fallback=None,
    description="paper-[5] baseline: explicit Rr/Rs MMMs"))
paths.register(paths.PathSpec(
    name="sr", forward=forward_sr, ref=forward_dense,
    fused_level="none", tolerance=2e-4,
    complexity="O(N^2)", fallback=None,
    description="strength reduction + edge-major layout (Sec 3.1-3.3)"))
paths.register(paths.PathSpec(
    name="sr_split", forward=forward_sr_split, ref=forward_sr,
    fused_level="none", tolerance=2e-4,
    complexity="O(N^2)", fallback=None,
    description="SR + bilinear first-layer split + dense grid (XLA)"))
paths.register(paths.PathSpec(
    name="fused", forward=forward_fused, ref=forward_sr,
    fused_level="edge", pallas=True, tolerance=5e-4,
    complexity="O(N^2)", fallback="sr",
    residency_model=_fused_residency,
    description="Pallas edge kernel: B-construct + f_R + MMM3 in VMEM"))
paths.register(paths.PathSpec(
    name="fused_full", forward=forward_fused_full, ref=forward_sr,
    fused_level="full", pallas=True, tolerance=5e-4,
    complexity="O(N^2)", fallback="sr_split",
    residency_model=_fused_full_residency,
    description="whole-network Pallas kernel: x -> logits on-chip"))


def loss_fn(params, cfg: JediNetConfig, batch, *, forward: str = "sr"):
    """Softmax cross-entropy over the 5 jet classes.

    ``forward`` names any registered path; its params-transform hook
    (e.g. int8 quantization) is applied before the forward call, and
    Pallas-backed paths fall back to interpret mode off-TPU.

    NOTE: transform hooks are inference-time.  Training THROUGH a
    quantized path gets degenerate gradients (round() is flat — there
    is no straight-through estimator here); train on an fp32 path and
    quantize the trained weights at serving time.  Doing it anyway
    warns (see the ROADMAP "Full low-precision MXU pipeline" item for
    the planned STE/QAT trainer).
    """
    spec = paths.get(forward)
    if spec.quantized:
        warnings.warn(
            f"loss_fn through quantized path {forward!r}: the params "
            "transform rounds weights with no straight-through "
            "estimator, so gradients through the quantizer are "
            "degenerate (flat).  Train on an fp32 path and quantize at "
            "serving time — QAT/STE is the ROADMAP 'Full low-precision "
            "MXU pipeline' item.",
            UserWarning, stacklevel=2)
    kw = {}
    if spec.pallas and jax.default_backend() != "tpu":
        kw["interpret"] = True
    logits = spec.forward(spec.prepare_params(params), cfg, batch["x"], **kw)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["y"][..., None], axis=-1)[..., 0]
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return jnp.mean(nll), {"accuracy": acc}
