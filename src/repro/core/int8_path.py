"""int8 weight-quantized whole-network fused path.

The FPGA side of the paper is a fixed-point datapath: ap_fixed weights
sized per layer by the quantization-aware co-design loop (Sec. 4.2).
The TPU analogue is a quantized MXU path — weights stored in int8 with
symmetric per-tensor scales, activations and accumulation kept in fp32.
At trigger-tier batch sizes the step is weight-traffic bound (see
EXPERIMENTS.md §Roofline), so 4 bytes -> 1 byte of weight HBM is the
latency lever, exactly like the paper trading DSP precision for
initiation interval.

Since the sender-tiled kernel rework the dequantization happens
IN-KERNEL: the quantized layers' int8 tensors travel to VMEM at
1 byte/element (``fused_forward_full`` detects the ``"w_scale"`` keys
and threads the scales in), the MXU multiplies the raw integer values
upcast to the compute dtype, and each per-tensor scale folds into the
fp32 ACCUMULATOR — so the spec honestly declares ``weight_bytes=1`` and
the roofline bills 1-byte weight traffic everywhere at once.  The
quantized weights also reserve ~4x less VMEM residency, which the
per-path bucket policy (``PathSpec.bucket_ladder``) converts into a
deeper serving ladder than the fp32 twin earns.

This module is also the registry's proof of extension: the path is
registered ONLY here via :func:`~repro.core.paths.register_path`, yet
the serving engine, deadline batcher, ``trigger_serve --forward``
choices, ``benchmarks/run.py --paths all`` and the CI regression gate
all pick it up with zero edits — everything they need (params
quantizer, reference fn, tolerance, roofline weight bytes) rides on the
:class:`~repro.core.paths.PathSpec`.

Quantization scheme
-------------------
Per weight tensor W: ``scale = max|W| / 127``; ``W_q = round(W / scale)``
clipped to [-127, 127], stored as int8 next to the fp32 scale.  Biases
stay fp32.  The kernel computes ``(h @ W_q) * scale`` with fp32
accumulation — numerically the dequantized matmul (integer values up to
+-127 are exact in fp32), so the numerics are bit-identical to an
int8-weight MXU pass with an fp32 accumulator.  The reference fn sees
the SAME quantized params (spec contract: ``ref`` and ``forward`` both
receive the transformed params), so the declared tolerance measures
kernel fidelity, not quantization loss — the quantization loss itself
is characterized in the numerics tests.  :func:`dequantize_params`
survives as the HBM-boundary dequant (the PR-4 wrapper's scheme): it
feeds the XLA reference and the in-kernel-vs-boundary equivalence
tests.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.paths import register_path

#: Engine-vs-ref acceptance bar for the int8 path (fp32 accumulation:
#: same fidelity class as the fp32 fused kernel).
INT8_TOLERANCE = 5e-4


def quantize_params_int8(params):
    """Symmetric per-tensor int8 quantization of every MLP weight.

    Returns a pytree of the same ``{"fr"/"fo"/"phi": {"layers": [...]}}``
    shape with each layer's ``"w"`` replaced by the int8 tensor plus a
    ``"w_scale"`` fp32 scalar.  Keeping the ``"w"`` key means
    shape-driven helpers (``autotune.mlp_widths``) keep working on
    quantized params unchanged.
    """
    def qlayer(layer):
        w = jnp.asarray(layer["w"], jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12) / 127.0
        wq = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
        out = {"w": wq, "w_scale": scale}
        if "b" in layer:
            out["b"] = jnp.asarray(layer["b"], jnp.float32)
        return out

    return {name: {"layers": [qlayer(lp) for lp in mlp["layers"]]}
            for name, mlp in params.items()}


def dequantize_params(qparams):
    """fp32 view of int8-quantized params (``w = w_q * w_scale``).

    The PR-4 HBM-boundary dequant scheme: running the fused kernel on
    THIS output reads fp32 weights from HBM (4 B/element) — kept as the
    numerical twin the in-kernel dequant is tested against, and as the
    bridge for consumers that need fp32 weights (XLA reference paths).
    """
    def dqlayer(layer):
        out = {"w": layer["w"].astype(jnp.float32) * layer["w_scale"]}
        if "b" in layer:
            out["b"] = layer["b"]
        return out

    return {name: {"layers": [dqlayer(lp) for lp in mlp["layers"]]}
            for name, mlp in qparams.items()}


def _ref_int8(qparams, cfg, x):
    """Reference: strength-reduced XLA forward on the dequantized weights."""
    from repro.core.interaction_net import forward_sr
    return forward_sr(dequantize_params(qparams), cfg, x)


def _int8_residency(cfg, qparams, batch, **kw):
    # Same sender-tiled kernel and tuner as fused_full; ``qparams`` is
    # already quantized, so weight_vmem_bytes bills int8 tensors at
    # 1 byte and the model reflects the smaller residency honestly.
    from repro.kernels.fused_jedinet.autotune import modeled_residency
    return modeled_residency(cfg, qparams, batch, **kw)


@register_path(
    name="int8_fused_full",
    ref=_ref_int8,
    fused_level="full",
    pallas=True,
    compute_dtypes=("float32",),      # int8 weights dequantize to fp32 compute
    transform_params=quantize_params_int8,
    tolerance=INT8_TOLERANCE,
    quantized=True,
    # The kernel loads int8 into VMEM and dequantizes on-chip (scale
    # folded after the fp32 accumulate), so the roofline honestly bills
    # 1 byte/weight of HBM traffic — this one field flips the model for
    # every consumer (engine roofline, codesign, benchmarks, CI gate).
    weight_bytes=1,
    # Degradation ladder (serving/resilient.py): a failing int8 kernel
    # demotes to the fp32 fused kernel, which itself bottoms out in the
    # XLA reference — int8_fused_full -> fused_full -> sr_split.
    fallback="fused_full",
    complexity="O(N^2)",
    residency_model=_int8_residency,
    description="int8-weight whole-network kernel, in-VMEM dequant",
)
def forward_int8_fused_full(qparams, cfg, x, *, interpret: bool = False):
    """Whole-network fused forward with int8 weights dequantized in-kernel.

    ``qparams`` is the output of :func:`quantize_params_int8` (the
    spec's params-transform hook applies it automatically wherever the
    path is resolved through the registry).  The int8 tensors are passed
    to the kernel VERBATIM — no fp32 materialization outside VMEM.
    """
    from repro.kernels.fused_jedinet import ops as fused_ops
    return fused_ops.fused_forward_full(qparams, cfg, x, interpret=interpret)
