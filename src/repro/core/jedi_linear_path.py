"""JEDI-linear forward paths: O(N_o) aggregation, registered end-to-end.

JEDI-linear (arXiv 2508.15468) keeps f_R's first layer linear so the
pairwise message sum commutes with it: the N_o x (N_o-1) edge grid that
dominates JEDI-net's FLOPs at N_o=128+ collapses into globally-pooled
sender projections — O(N_o) aggregation (``kernels/jedi_linear/``).
This is a *different model* from JEDI-net (the first nonlinearity sees
the aggregated message), so the paths here carry their own reference —
the O(N_o^2) edge-sum oracle, which evaluates the same model WITHOUT
the pooling rearrangement and therefore independently validates the
identity — their own tolerance, and their own accuracy story
(EXPERIMENTS.md §JEDI-linear).

Three paths, one degradation ladder::

    int8_jedi_linear_full -> jedi_linear_full -> jedi_linear -> sr_split

* ``jedi_linear``           — the O(N_o) pooled forward in plain XLA: the
  non-Pallas rung every jedi kernel demotes to, and a servable
  production fallback in its own right.
* ``jedi_linear_full``      — the fused Pallas kernel: x -> logits
  on-chip, batch-tiled under the LINEAR live-set model (no sender
  axis), fp32 accumulation.
* ``int8_jedi_linear_full`` — int8 weights dequantized in-kernel
  (scales folded into the fp32 accumulator), ``weight_bytes=1``.

All three declare ``complexity="O(N)"`` and plug
:func:`~repro.core.codesign.jedi_linear_flops` into the per-path FLOPs
hook, so rooflines and codesign bill O(N_o) compute — at N_o=128 the
edge-grid model would overcharge them ~40x.  Like ``int8_path``, this
module is pure registration: the engine, ResilientEngine ladder, CLIs,
benchmarks and CI gate pick the paths up by introspection with zero
consumer edits.
"""

from __future__ import annotations

from repro.core.int8_path import (
    INT8_TOLERANCE,
    dequantize_params,
    quantize_params_int8,
)
from repro.core.paths import register_path

#: Engine-vs-ref acceptance bar for the fp32/bf16 jedi_linear paths.
#: The pooled identity is exact in exact arithmetic; fp32 accumulation
#: leaves only summation-order noise (measured < 3e-5 at N_o=128), so
#: the XLA path holds the reference-class bar and the Pallas kernel the
#: fused-kernel-class bar.
JEDI_LINEAR_TOLERANCE = 2e-4
JEDI_LINEAR_FUSED_TOLERANCE = 5e-4


def _jedi_flops(cfg, batch):
    """PathSpec.flops_model hook -> :func:`codesign.jedi_linear_flops`
    (imported lazily: codesign pulls in the DSE machinery)."""
    from repro.core.codesign import jedi_linear_flops
    return jedi_linear_flops(cfg, batch)


def _linear_per_sample_bytes(cfg, params):
    """PathSpec.per_sample_bytes hook: the LINEAR live-set model — no
    sender axis, so the serving bucket ladder deepens by ~block_s vs
    the grid kernels' sender-tiled estimate."""
    from repro.kernels.autotune import mlp_widths
    from repro.kernels.jedi_linear.autotune import (
        linear_forward_bytes_per_sample)
    return linear_forward_bytes_per_sample(
        cfg.n_objects, cfg.n_features, mlp_widths(params["fr"]),
        mlp_widths(params["fo"]), mlp_widths(params["phi"]))


def _linear_residency(cfg, params, batch, **kw):
    """PathSpec.residency_model hook: the kernel autotuner's tiling
    decision as data, for the static kernel-contract auditor."""
    from repro.kernels.jedi_linear.autotune import modeled_residency
    return modeled_residency(cfg, params, batch, **kw)


def _ref_edge_sum(params, cfg, x):
    """Reference: the O(N_o^2) edge-sum oracle of the SAME model."""
    from repro.kernels.jedi_linear.ref import forward_jedi_linear_edge_sum
    return forward_jedi_linear_edge_sum(params, cfg, x)


def _ref_edge_sum_int8(qparams, cfg, x):
    """Reference for the int8 path: edge-sum oracle on dequantized
    weights (spec contract: ref sees the transformed params, so the
    declared tolerance measures kernel fidelity, not quantization
    loss)."""
    from repro.kernels.jedi_linear.ref import forward_jedi_linear_edge_sum
    return forward_jedi_linear_edge_sum(dequantize_params(qparams), cfg, x)


@register_path(
    name="jedi_linear",
    ref=_ref_edge_sum,
    # "edge": no B/E edge tensors exist to round-trip (there is no edge
    # grid at all), but Ebar and O still cross XLA fusion boundaries —
    # the same traffic band as the edge-fused kernel, and nothing like
    # the "none" tier's N_E-sized round-trips.
    fused_level="edge",
    tolerance=JEDI_LINEAR_TOLERANCE,
    complexity="O(N)",
    flops_model=_jedi_flops,
    per_sample_bytes=_linear_per_sample_bytes,
    # Non-Pallas rung of the jedi ladder; bottoms out in the O(N^2)
    # XLA reference so a jedi-specific numerical surprise still serves.
    fallback="sr_split",
    description="JEDI-linear O(N) pooled aggregation (XLA)",
)
def forward_jedi_linear(params, cfg, x):
    """O(N_o) JEDI-linear forward in plain XLA (see kernels/jedi_linear)."""
    from repro.kernels.jedi_linear.ref import forward_jedi_linear as fwd
    return fwd(params, cfg, x)


@register_path(
    name="jedi_linear_full",
    ref=_ref_edge_sum,
    fused_level="full",
    pallas=True,
    tolerance=JEDI_LINEAR_FUSED_TOLERANCE,
    complexity="O(N)",
    flops_model=_jedi_flops,
    per_sample_bytes=_linear_per_sample_bytes,
    # Degradation ladder: a failing jedi kernel demotes to the SAME
    # model in XLA first (accuracy story unchanged), then to sr_split.
    fallback="jedi_linear",
    residency_model=_linear_residency,
    description="JEDI-linear whole-network Pallas kernel, O(N) on-chip",
)
def forward_jedi_linear_full(params, cfg, x, *, interpret: bool = False):
    """Fused JEDI-linear forward: the whole x -> logits pipeline in one
    Pallas kernel per batch tile."""
    from repro.kernels.jedi_linear import ops as jl_ops
    return jl_ops.jedi_linear_forward_full(params, cfg, x,
                                           interpret=interpret)


@register_path(
    name="int8_jedi_linear_full",
    ref=_ref_edge_sum_int8,
    fused_level="full",
    pallas=True,
    compute_dtypes=("float32",),      # int8 weights dequantize to fp32 compute
    transform_params=quantize_params_int8,
    tolerance=max(JEDI_LINEAR_FUSED_TOLERANCE, INT8_TOLERANCE),
    quantized=True,
    weight_bytes=1,                   # in-kernel dequant: 1 B/weight HBM
    complexity="O(N)",
    flops_model=_jedi_flops,
    per_sample_bytes=_linear_per_sample_bytes,
    fallback="jedi_linear_full",
    residency_model=_linear_residency,
    description="int8-weight JEDI-linear kernel, in-VMEM dequant",
)
def forward_int8_jedi_linear_full(qparams, cfg, x, *, interpret: bool = False):
    """Fused JEDI-linear forward with int8 weights dequantized in-kernel
    (``qparams`` from :func:`quantize_params_int8`, applied by the
    spec's transform hook wherever the path resolves through the
    registry)."""
    from repro.kernels.jedi_linear import ops as jl_ops
    return jl_ops.jedi_linear_forward_full(qparams, cfg, x,
                                           interpret=interpret)
