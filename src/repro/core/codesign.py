"""Algorithm-hardware co-design (Sec. 4.2-4.4 of the paper).

Two analytic performance models drive the design-space exploration:

* ``FPGAModel`` — the paper's own resource model (eq. 1) and latency model
  (eq. 2) for the fused layer-wise HLS architecture on a Xilinx U250.
  This is the faithful reproduction: it regenerates the II / latency columns
  of Table 2 and the <5% latency-prediction error claimed in Sec. 5.4.5.

* ``TPUModel`` — our hardware adaptation: a three-term roofline estimate
  (MXU compute, HBM traffic, ICI collectives) of a *batched* JEDI-net
  inference step on TPU v5e.  The FPGA streams one jet at a time through a
  spatial pipeline; a TPU amortizes weight traffic over a batch, so the
  co-design trade-off shifts from DSP count vs II to arithmetic intensity
  vs HBM bandwidth.  The search space and the accuracy proxy are identical,
  only the cost model is swapped — which is exactly the point of the
  paper's co-design framework being "easily switched to other user-defined
  metrics" (Sec. 4.4).

The DSE (``explore``) enumerates (f_R NL/size, f_O first-layer size, N_fR)
candidates, prunes by alpha x latency budget *before* any training — the
paper's trick for cutting GPU training hours — and returns Opt-Latn /
Opt-Acc picks per the paper's J4/J5/U4/U5 selection rule.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Sequence

from repro.core.interaction_net import JediNetConfig

# --- hardware constants ----------------------------------------------------

U250_DSPS = 12288            # Table 1
FPGA_CLOCK_NS = 5.0          # 200 MHz (Sec. 5.1)

TPU_V5E_BF16_FLOPS = 197e12  # per chip
TPU_V5E_HBM_BPS = 819e9
TPU_V5E_ICI_BPS = 50e9       # per link


# ---------------------------------------------------------------------------
# FPGA model (faithful): eq. (1) DSPs + eq. (2) latency.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FPGADesignPoint:
    cfg: JediNetConfig
    n_fr: int                 # copies of the f_R unit (N_fR)
    r_fo: int = 1             # reuse factor of f_O
    r_phi: int = 1            # reuse factor of phi_O
    ii_mult: int = 1          # II of a DSP multiplier (1 cycle, Sec 4.3)

    # Pipeline-depth constants of eq. (2).  DP_loop + DP_tail is dominated by
    # the depth of the fused stage: each GEMM stage adds a few register
    # stages.  Calibrated on the paper's own J4/J5/U4/U5 estimates
    # (0.30/0.91/0.66/0.915 us -> depths 29..37 for 7..11 MLP matmul stages).
    dp_per_matmul: float = 2.0
    dp_base: float = 11.0


class FPGAModel:
    """Eq. (1) resource + eq. (2) latency model."""

    @staticmethod
    def mlp_layer_dims(cfg: JediNetConfig):
        from repro.nn.core import mlp_dims
        return {
            "fr": mlp_dims(2 * cfg.n_features, list(cfg.fr_hidden), cfg.d_e),
            "fo": mlp_dims(cfg.n_features + cfg.d_e, list(cfg.fo_hidden), cfg.d_o),
            "phi": mlp_dims(cfg.d_o, list(cfg.phi_hidden), cfg.n_targets),
        }

    @classmethod
    def dsp_count(cls, pt: FPGADesignPoint) -> int:
        """eq. (1): DSP_layer = FC_in*FC_out / R_NN, summed, x N_NN copies."""
        dims = cls.mlp_layer_dims(pt.cfg)
        reuse = {"fr": 1, "fo": pt.r_fo, "phi": pt.r_phi}   # R_fR == 1 always
        copies = {"fr": pt.n_fr, "fo": 1, "phi": 1}
        total = 0
        for nn_name, layer_dims in dims.items():
            per_copy = sum(math.ceil(din * dout / reuse[nn_name])
                           for din, dout in layer_dims)
            total += per_copy * copies[nn_name]
        return total

    @classmethod
    def latency_cycles(cls, pt: FPGADesignPoint) -> dict:
        """eq. (2): II and end-to-end latency of the fused design, in cycles."""
        cfg = pt.cfg
        n_o = cfg.n_objects
        ii_loop = pt.ii_mult * max(
            math.ceil((n_o - 1) / pt.n_fr), pt.r_fo, pt.r_phi)
        ii_model = ii_loop * n_o
        dims = cls.mlp_layer_dims(cfg)
        n_matmuls = sum(len(d) for d in dims.values())
        dp = pt.dp_per_matmul * n_matmuls + pt.dp_base
        latency = ii_loop * (n_o - 1) + dp
        return {
            "ii_loop": ii_loop,
            "ii_cycles": ii_model,
            "latency_cycles": latency,
            "ii_us": ii_model * FPGA_CLOCK_NS / 1e3,
            "latency_us": latency * FPGA_CLOCK_NS / 1e3,
        }

    @classmethod
    def evaluate(cls, pt: FPGADesignPoint) -> dict:
        out = cls.latency_cycles(pt)
        out["dsp"] = cls.dsp_count(pt)
        out["dsp_util"] = out["dsp"] / U250_DSPS
        out["fits"] = out["dsp"] <= U250_DSPS
        return out


# ---------------------------------------------------------------------------
# TPU model (adaptation): roofline estimate for a batched inference step.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUDesignPoint:
    cfg: JediNetConfig
    batch: int = 1024
    chips: int = 1
    compute_bytes: int = 2    # bf16


class TPUModel:
    """Three-term roofline for one batched JEDI-net inference."""

    @staticmethod
    def flops(cfg: JediNetConfig, batch: int) -> float:
        from repro.nn.core import mlp_dims
        n_e, n_o = cfg.n_edges, cfg.n_objects
        f = 0.0
        for din, dout in mlp_dims(2 * cfg.n_features, list(cfg.fr_hidden), cfg.d_e):
            f += 2.0 * n_e * din * dout
        for din, dout in mlp_dims(cfg.n_features + cfg.d_e, list(cfg.fo_hidden), cfg.d_o):
            f += 2.0 * n_o * din * dout
        for din, dout in mlp_dims(cfg.d_o, list(cfg.phi_hidden), cfg.n_targets):
            f += 2.0 * din * dout
        # strength-reduced MMM3 adds: D_e * N_E (Fig. 8) — negligible but real.
        f += cfg.d_e * n_e
        return f * batch

    # O(N) paths override this via PathSpec.flops_model = jedi_linear_flops.

    @staticmethod
    def hbm_bytes(cfg: JediNetConfig, batch: int, compute_bytes: int,
                  level: str = "edge", *,
                  weight_bytes: int | None = None) -> float:
        """HBM traffic: weights once per step + activation round-trips.

        ``level`` is a :data:`~repro.core.paths.FUSED_LEVELS` tier:

        * ``"none"`` — unfused path: B and E round-trip through HBM;
        * ``"edge"`` — edge-only kernel: B/E stay in VMEM, Ebar and O
          still cross the kernel/XLA boundary;
        * ``"full"`` — whole-network kernel: weights + x in, logits out.

        Each tier removes one band of activation traffic (what the
        fused-vs-unfused §Perf iteration measures).  ``weight_bytes``
        overrides the weight precision independently of the activation
        ``compute_bytes`` — quantized paths (int8 weights, fp32
        accumulation) bill 1 B/weight while activations stay wide.

        The legacy ``fused: bool | str`` argument is gone: ``False``
        used to coerce surprisingly (a falsy level is not a fusion
        statement), so anything but an exact tier name now raises.
        """
        from repro.core.paths import FUSED_LEVELS
        from repro.nn.core import mlp_dims
        if level not in FUSED_LEVELS:
            raise ValueError(
                f"fused level must be one of {FUSED_LEVELS}, got {level!r}")
        cfgs = [
            mlp_dims(2 * cfg.n_features, list(cfg.fr_hidden), cfg.d_e),
            mlp_dims(cfg.n_features + cfg.d_e, list(cfg.fo_hidden), cfg.d_o),
            mlp_dims(cfg.d_o, list(cfg.phi_hidden), cfg.n_targets),
        ]
        w = sum((din * dout + dout) for dims in cfgs for din, dout in dims)
        traffic = w * (compute_bytes if weight_bytes is None else weight_bytes)
        n_e, n_o = cfg.n_edges, cfg.n_objects
        act = n_o * cfg.n_features                     # input
        act += cfg.n_targets                           # logits
        if level in ("none", "edge"):
            act += n_o * cfg.d_e                       # Ebar kernel<->XLA
            act += n_o * cfg.d_o                       # O
        if level == "none":
            act += 2 * (n_e * 2 * cfg.n_features)      # B write + read
            act += 2 * (n_e * cfg.d_e)                 # E write + read
        return traffic + act * batch * compute_bytes

    @classmethod
    def evaluate(cls, pt: TPUDesignPoint, level: str = "edge", *,
                 weight_bytes: int | None = None,
                 flops_fn: Callable | None = None) -> dict:
        """``flops_fn`` — per-path FLOPs model ``(cfg, batch) -> float``
        (``PathSpec.flops_model``); ``None`` uses the dense edge-grid
        :meth:`flops`.  O(N) paths plug in :func:`jedi_linear_flops` so
        the compute term of the roofline matches their algorithmic
        class — at N_o=128 the two differ by ~40x."""
        fl = (flops_fn or cls.flops)(pt.cfg, pt.batch)
        by = cls.hbm_bytes(pt.cfg, pt.batch, pt.compute_bytes, level,
                           weight_bytes=weight_bytes)
        t_c = fl / (pt.chips * TPU_V5E_BF16_FLOPS)
        t_m = by / (pt.chips * TPU_V5E_HBM_BPS)
        return {
            "flops": fl,
            "hbm_bytes": by,
            "compute_s": t_c,
            "memory_s": t_m,
            "step_us": max(t_c, t_m) * 1e6,
            "bound": "compute" if t_c >= t_m else "memory",
            "arithmetic_intensity": fl / by,
            "fused_level": level,
            "weight_bytes": pt.compute_bytes if weight_bytes is None
            else weight_bytes,
        }


def jedi_linear_flops(cfg: JediNetConfig, batch: int) -> float:
    """FLOPs of one batched JEDI-linear forward (O(N_o) aggregation).

    The pooled identity (``kernels/jedi_linear/ref.py``) moves the
    sender sum in front of f_R's first nonlinearity, so EVERY f_R layer
    runs over N_o node rows instead of N_E = N_o(N_o-1) edge rows — the
    first-layer GEMM cost is unchanged (the split halves sum to one
    (2P x H1) projection over N_o rows) and the pool + recombination
    add only ~4 N_o H1 elementwise ops.  f_O / phi_O are identical to
    the dense model.  The per-path FLOPs hook of the jedi_linear specs
    (``PathSpec.flops_model``).
    """
    from repro.nn.core import mlp_dims
    n_o = cfg.n_objects
    f = 0.0
    for din, dout in mlp_dims(2 * cfg.n_features, list(cfg.fr_hidden),
                              cfg.d_e):
        f += 2.0 * n_o * din * dout
    for din, dout in mlp_dims(cfg.n_features + cfg.d_e, list(cfg.fo_hidden),
                              cfg.d_o):
        f += 2.0 * n_o * din * dout
    for din, dout in mlp_dims(cfg.d_o, list(cfg.phi_hidden), cfg.n_targets):
        f += 2.0 * din * dout
    # sender pool + (N_o-1)-recombination: ~4 elementwise ops per (node, H1)
    h1 = (list(cfg.fr_hidden) + [cfg.d_e])[0]
    f += 4.0 * n_o * h1
    return f * batch


def bucket_roofline(cfg: JediNetConfig, buckets, *, level: str = "full",
                    compute_bytes: int = 2, chips: int = 1,
                    weight_bytes: int | None = None,
                    flops_fn: Callable | None = None) -> dict:
    """TPUModel roofline per serving bucket size.

    The batcher pads requests up to ladder buckets, so the question "what
    should this dispatch cost?" is per BUCKET, not per request: small
    buckets are weight-traffic (memory) bound — every padded row rides a
    fixed HBM bill — while large buckets amortize weights and go
    compute-bound.  Returns ``{bucket: evaluate() dict + per_event_us}``;
    the crossover is where the deadline/throughput trade-off lives.

    ``level`` / ``weight_bytes`` / ``flops_fn`` normally come off a
    :class:`~repro.core.paths.PathSpec` (``spec.roofline_for`` wraps
    this fn) so the model always matches what the path actually fuses —
    and, via the per-path FLOPs hook, its algorithmic class.
    """
    out = {}
    for b in buckets:
        m = TPUModel.evaluate(
            TPUDesignPoint(cfg=cfg, batch=int(b), chips=chips,
                           compute_bytes=compute_bytes), level,
            weight_bytes=weight_bytes, flops_fn=flops_fn)
        m["per_event_us"] = m["step_us"] / int(b)
        out[int(b)] = m
    return out


def path_bucket_policy(spec, cfg: JediNetConfig, params, *,
                       max_batch: int = 1024, compute_bytes: int = 2,
                       chips: int = 1, roofline: bool = True) -> dict:
    """One forward path's resolved serving policy + roofline, in one dict.

    The co-design view of the per-path bucket policy: the path's OWN
    VMEM model (``spec.bucket_bytes``), its weight-residency reservation
    (``spec.reserved_vmem_bytes`` — int8 weights reserve ~4x less, so
    quantized paths earn deeper ladders), the ladder those produce, and
    the TPUModel roofline per rung at the path's fusion level and weight
    precision.  ``params`` are RAW; the spec's transform hook (e.g. int8
    quantization) is applied here so the reservation reflects the
    serving dtype.  ``paths.describe(cfg=..., params=...)`` — and so
    ``trigger_serve --list-paths`` — renders its output; the engine
    resolves the same policy through ``spec.bucket_ladder`` at
    construction.  ``roofline=False`` skips the per-rung TPUModel
    evaluation for consumers that only render the ladder.
    """
    pparams = spec.prepare_params(params)
    ladder = spec.bucket_ladder(cfg, pparams, max_batch)
    out = {
        "path": spec.name,
        "compute_dtypes": tuple(spec.compute_dtypes),
        "weight_bytes": spec.weight_bytes,
        "per_sample_bytes": spec.bucket_bytes(cfg, pparams),
        "reserved_vmem_bytes": spec.reserved_vmem_bytes(cfg, pparams),
        "bucket_ladder": ladder,
    }
    if roofline:
        out["roofline"] = spec.roofline_for(cfg, ladder,
                                            compute_bytes=compute_bytes,
                                            chips=chips)
    return out


# ---------------------------------------------------------------------------
# Design-space exploration (Sec. 4.4).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Candidate:
    cfg: JediNetConfig
    n_fr: int
    r_fo: int
    fpga: dict
    tpu: dict
    accuracy: float | None = None   # filled in only for surviving candidates


def candidate_space(base: JediNetConfig,
                    fr_nl: Sequence[int] = (1, 2, 3, 4),
                    fr_size: Sequence[int] = (8, 16, 24, 32),
                    fo_first: Sequence[int] = (16, 32, 48, 64, 96),
                    n_fr_opts: Sequence[int] | None = None,
                    r_fo_opts: Sequence[int] = (1, 2, 4)):
    """Enumerate the paper's search space (Sec. 5.4.4).

    f_O / phi_O keep their layer count; only f_O's first hidden layer is
    re-sized, exactly as in the paper ("we keep the layer number and other
    configurations of f_O and phi_O the same to [5] but only set the size of
    their first layer").
    """
    if n_fr_opts is None:
        n_fr_opts = sorted({1, 2, 3, 4, 6, 8, 10, 13, 17, 25, 29,
                            base.n_objects - 1})
    for nl, s, fo1, n_fr, r_fo in itertools.product(
            fr_nl, fr_size, fo_first, n_fr_opts, r_fo_opts):
        fo_hidden = (fo1, *base.fo_hidden[1:])
        cfg = base.with_(fr_hidden=tuple([s] * nl), fo_hidden=fo_hidden)
        yield cfg, n_fr, r_fo


def explore(base: JediNetConfig,
            latency_budget_us: float = 1.0,
            alpha: float = 2.0,
            dsp_slack: float = 1.0,
            accuracy_proxy: Callable[[JediNetConfig], float] | None = None,
            max_candidates: int | None = None,
            fused_level: str = "full",
            **space_kw) -> dict:
    """Run the co-design DSE.

    1. enumerate candidates,
    2. evaluate the *analytic* FPGA latency + DSP models (cheap),
    3. prune: DSP > budget, or latency > alpha x budget (skip training),
    4. score survivors with `accuracy_proxy` (a trained-model eval in
       production; a capacity-based proxy in tests/benchmarks),
    5. return Opt-Latn (min latency, ties by accuracy) and Opt-Acc
       (max accuracy with latency <= budget).
    """
    survivors: list[Candidate] = []
    n_total = n_pruned_dsp = n_pruned_lat = 0
    for cfg, n_fr, r_fo in candidate_space(base, **space_kw):
        n_total += 1
        if max_candidates and n_total > max_candidates:
            break
        pt = FPGADesignPoint(cfg=cfg, n_fr=n_fr, r_fo=r_fo)
        fpga = FPGAModel.evaluate(pt)
        # eq. (1) is an upper bound: Vivado HLS shares DSPs across the fused
        # loop (Table 1 reports ~1.8-3x fewer DSPs than eq. 1 predicts for
        # J3..U5), so the budget check allows a calibrated slack factor.
        fpga["fits"] = fpga["dsp"] <= U250_DSPS * dsp_slack
        if not fpga["fits"]:
            n_pruned_dsp += 1
            continue
        if fpga["latency_us"] > alpha * latency_budget_us:
            n_pruned_lat += 1
            continue
        # model the best available kernel (the whole-network fusion) by
        # default; pass fused_level="edge"/"none" to study the others.
        tpu = TPUModel.evaluate(TPUDesignPoint(cfg=cfg), fused_level)
        survivors.append(Candidate(cfg=cfg, n_fr=n_fr, r_fo=r_fo,
                                   fpga=fpga, tpu=tpu))

    if accuracy_proxy is None:
        accuracy_proxy = capacity_accuracy_proxy
    for c in survivors:
        c.accuracy = accuracy_proxy(c.cfg)

    opt_latn = min(
        survivors, key=lambda c: (c.fpga["latency_us"], -c.accuracy),
        default=None)
    in_budget = [c for c in survivors if c.fpga["latency_us"] <= latency_budget_us]
    opt_acc = max(in_budget, key=lambda c: c.accuracy, default=None)
    return {
        "n_total": n_total,
        "n_pruned_dsp": n_pruned_dsp,
        "n_pruned_latency": n_pruned_lat,
        "n_survivors": len(survivors),
        "survivors": survivors,
        "opt_latn": opt_latn,
        "opt_acc": opt_acc,
        "training_runs_saved": n_pruned_dsp + n_pruned_lat,
    }


def capacity_accuracy_proxy(cfg: JediNetConfig) -> float:
    """Cheap monotone proxy for model accuracy used when no trained eval is
    plugged in: saturating log-capacity of the three MLPs.  The paper's
    observation (Sec 4.4) is that accuracy is far less sensitive to f_R's
    size than latency is — so the proxy weights f_O capacity higher.
    """
    from repro.nn.core import mlp_dims
    cap_fr = sum(i * o for i, o in mlp_dims(2 * cfg.n_features,
                                            list(cfg.fr_hidden), cfg.d_e))
    cap_fo = sum(i * o for i, o in mlp_dims(cfg.n_features + cfg.d_e,
                                            list(cfg.fo_hidden), cfg.d_o))
    cap_phi = sum(i * o for i, o in mlp_dims(cfg.d_o, list(cfg.phi_hidden),
                                             cfg.n_targets))
    return 70.0 + 2.2 * math.log10(1 + cap_fr) + 3.0 * math.log10(1 + cap_fo) \
        + 0.8 * math.log10(1 + cap_phi)


# --- paper Table 2 reference points (for the fidelity benchmark) -----------

def paper_table2_points() -> list[dict]:
    """The J1..J5 / U1..U5 design points with published II / latency."""
    j30 = dict(n_objects=30, n_features=16, d_e=8, d_o=24)
    u50 = dict(n_objects=50, n_features=16, d_e=8, d_o=24)
    mk = lambda base, fr, fo, nfr, rfo: dict(
        cfg=JediNetConfig(**base, fr_hidden=fr, fo_hidden=fo, phi_hidden=fo),
        n_fr=nfr, r_fo=rfo)
    return [
        dict(name="J1", **mk(j30, (20,) * 3, (20,) * 3, 1, 1),
             paper_ii_cycles=880, paper_latency_cycles=2511),
        dict(name="J2", **mk(j30, (20,) * 3, (20,) * 3, 13, 1),
             paper_ii_cycles=80, paper_latency_cycles=382),
        dict(name="J3", **mk(j30, (8,) * 1, (48,) * 3, 10, 1),
             paper_ii_cycles=90, paper_latency_cycles=124),
        dict(name="J4", **mk(j30, (8,) * 1, (48,) * 3, 29, 1),
             paper_ii_cycles=30, paper_latency_cycles=58),
        dict(name="J5", **mk(j30, (32,) * 2, (48,) * 3, 6, 1),
             paper_ii_cycles=150, paper_latency_cycles=181),
        dict(name="U1", **mk(u50, (50,) * 3, (50,) * 3, 1, 1),
             paper_ii_cycles=2462, paper_latency_cycles=6519),
        dict(name="U2", **mk(u50, (50,) * 3, (50,) * 3, 3, 1),
             paper_ii_cycles=854, paper_latency_cycles=2493),
        dict(name="U3", **mk(u50, (50,) * 3, (50,) * 3, 4, 4),
             paper_ii_cycles=650, paper_latency_cycles=2131),
        dict(name="U4", **mk(u50, (8,) * 2, (32,) * 3, 25, 1),
             paper_ii_cycles=100, paper_latency_cycles=130),
        dict(name="U5", **mk(u50, (8,) * 2, (48,) * 3, 17, 1),
             paper_ii_cycles=150, paper_latency_cycles=181),
    ]
