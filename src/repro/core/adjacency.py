"""Fully-connected interaction-graph structure and its strength reduction.

The LL-GNN paper's first contribution (Sec. 3.1) is a *code transformation
with strength reduction* for the three matrix-matrix multiplications of the
interaction network:

    MMM1:  B1 = I @ Rr        (receiver features per edge)
    MMM2:  B2 = I @ Rs        (sender   features per edge)
    MMM3:  Ebar = E @ Rr^T    (sum of incoming edge messages per node)

For a fully connected graph with N_o nodes, Rr and Rs are binary (N_o, N_E)
matrices with one-hot columns and a *fixed, static* pattern:

    edge e = i*(N_o-1) + k   has   receiver(e) = i
                                   sender(e)   = k if k < i else k + 1

so MMM1/MMM2 degenerate into pure loads/stores (a broadcast and a static
gather) and MMM3 degenerates into a reshape + sum over the k axis — no
multiplications, no adjacency matrix in memory, no irregular access.

TPU adaptation (see DESIGN.md): the FPGA design fuses the static pattern
into HLS loop indices; on TPU we fuse it into *array layout*.  Edges are laid
out receiver-major so that

    B1   = broadcast of node features over the k axis       (a reshape)
    B2   = one static gather with a compile-time index map   (XLA constant)
    Ebar = reshape (N_o, N_o-1, D_e) + sum over axis 1       (a reduction)

which is exactly the paper's "only loads/stores + 1/N_o of the additions",
expressed in a form the XLA/Mosaic compilers turn into contiguous VMEM
traffic.  The dense matrices are retained only as the paper-[5] baseline and
as the oracle for tests.
"""

from __future__ import annotations

from functools import lru_cache

import numpy as np


@lru_cache(maxsize=None)
def edge_index_maps(n_obj: int) -> tuple[np.ndarray, np.ndarray]:
    """Static (receivers, senders) index vectors for the FC interaction graph.

    Edge ordering is receiver-major: e = i*(n_obj-1) + k, matching
    Algorithm 1 of the paper.  Both arrays have shape (n_obj*(n_obj-1),).
    """
    if n_obj < 2:
        raise ValueError("interaction graph needs at least 2 objects")
    i = np.repeat(np.arange(n_obj), n_obj - 1)
    k = np.tile(np.arange(n_obj - 1), n_obj)
    senders = np.where(k < i, k, k + 1)
    return i.astype(np.int32), senders.astype(np.int32)


@lru_cache(maxsize=None)
def sender_index_matrix(n_obj: int) -> np.ndarray:
    """(n_obj, n_obj-1) matrix of sender indices, row i = senders of receiver i.

    Row i is [0, 1, ..., i-1, i+1, ..., n_obj-1]: the paper's
    ``index = (k < i) ? k : k + 1`` from Algorithm 1.
    """
    _, senders = edge_index_maps(n_obj)
    return senders.reshape(n_obj, n_obj - 1)


@lru_cache(maxsize=None)
def dense_relation_matrices(n_obj: int) -> tuple[np.ndarray, np.ndarray]:
    """Dense one-hot Rr, Rs of shape (n_obj, n_E) — the paper-[5] baseline.

    Only used by the unoptimized reference path and the tests; the
    strength-reduced path never materializes these.
    """
    receivers, senders = edge_index_maps(n_obj)
    n_e = n_obj * (n_obj - 1)
    rr = np.zeros((n_obj, n_e), dtype=np.float32)
    rs = np.zeros((n_obj, n_e), dtype=np.float32)
    rr[receivers, np.arange(n_e)] = 1.0
    rs[senders, np.arange(n_e)] = 1.0
    return rr, rs


def mmm_op_counts(n_obj: int, n_feat: int, d_e: int) -> dict:
    """Multiply/add/iteration counts for MMM1/2/3, baseline vs strength-reduced.

    Reproduces Fig. 8 of the paper analytically (benchmarked in
    ``benchmarks/bench_ops_reduction.py``):

    * baseline MMM1 (I @ Rr): P x N_o x N_E mults, P x (N_o-1) x N_E adds
    * baseline MMM3 (E @ Rr^T): D_e x N_E x N_o mults, D_e x (N_E-1) x N_o adds
    * strength-reduced MMM1/2: zero mults / zero adds (loads+stores only)
    * strength-reduced MMM3:  zero mults, D_e x N_E adds
    * iterations: N_o x (N_o-1) -> (N_o - 1) per the 1-hot reduction
    """
    n_e = n_obj * (n_obj - 1)
    return {
        "n_edges": n_e,
        "mmm12_baseline_mults": n_feat * n_obj * n_e,
        "mmm12_baseline_adds": n_feat * (n_obj - 1) * n_e,
        "mmm12_sr_mults": 0,
        "mmm12_sr_adds": 0,
        "mmm3_baseline_mults": d_e * n_e * n_obj,
        "mmm3_baseline_adds": d_e * (n_e - 1) * n_obj,
        "mmm3_sr_mults": 0,
        "mmm3_sr_adds": d_e * n_e,
        "iterations_baseline": n_obj * (n_obj - 1),
        "iterations_sr": n_obj - 1,
    }
