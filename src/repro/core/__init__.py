"""LL-GNN core: interaction-network JEDI-net with strength reduction,
edge-major layout, fused execution and algorithm-hardware co-design."""

from repro.core.adjacency import (
    edge_index_maps,
    sender_index_matrix,
    dense_relation_matrices,
    mmm_op_counts,
)
from repro.core.interaction_net import (
    JediNetConfig,
    init,
    forward_dense,
    forward_sr,
    forward_fused,
    build_b_matrix,
    aggregate_incoming,
    loss_fn,
)
from repro.core import codesign, paths

__all__ = [
    "edge_index_maps", "sender_index_matrix", "dense_relation_matrices",
    "mmm_op_counts", "JediNetConfig", "init", "forward_dense", "forward_sr",
    "forward_fused", "build_b_matrix", "aggregate_incoming", "loss_fn",
    "codesign", "paths",
]
