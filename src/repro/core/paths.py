"""First-class forward-path registry: one declarative API per path.

The paper's co-design loop (Sec. 4.4) works because every candidate
design exposes the same knobs — precision, fusion level, parallelism —
through one hardware template.  This module is the software analogue:
a :class:`PathSpec` declaratively bundles everything a forward path
*is* — the forward fn, its numerical reference, the fusion level the
roofline should model it at, supported compute dtypes, an optional
params-transform hook (e.g. quantization), the VMEM working-set model
the bucket ladder derives from, and a roofline hook — so the serving
engine, batcher, CLI, benchmarks and CI gate all introspect ONE object
instead of agreeing by convention across five files.

Registering a path makes it appear everywhere with zero consumer
edits::

    from repro.core.paths import register_path

    @register_path(name="my_path", ref=my_ref, fused_level="full",
                   tolerance=1e-4)
    def forward_my_path(params, cfg, x, *, interpret=False):
        ...

``paths.available()`` / ``paths.get(name)`` are the only lookups any
consumer performs; tag filters (``available(quantized=True)``,
``available(pallas=True)``, ``available(complexity="O(N)")``) answer
capability queries.  This registry IS the forward-path API: the
pre-registry surfaces (a flat forward-fn dict, lazy path-name
snapshots) are gone, and a repo-hygiene test keeps them gone.

Built-in paths live in the modules listed in :data:`_BUILTIN_MODULES`;
they are imported lazily on first registry access so importing
``repro.core.paths`` stays dependency-free (no jax work at import).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Sequence

#: The fusion tiers a path can achieve, in increasing order (see
#: ``codesign.TPUModel.hbm_bytes``): "none" round-trips B/E through HBM,
#: "edge" keeps them in VMEM, "full" keeps every intermediate on-chip.
FUSED_LEVELS = ("none", "edge", "full")

#: Algorithmic complexity classes in N_o (the aggregation strategy):
#: "O(N^2)" — the dense pairwise edge grid; "O(N)" — JEDI-linear
#: globally-pooled aggregation.  A validated vocabulary (not free text)
#: so ``available(complexity="O(N)")`` can never silently miss a typo'd
#: registration.
COMPLEXITY_CLASSES = ("O(N^2)", "O(N)")


@dataclasses.dataclass(frozen=True)
class PathSpec:
    """Everything one forward path is, in one declarative object.

    ``forward`` / ``ref`` share the signature ``(params, cfg, x) ->
    logits`` (Pallas-backed paths additionally accept ``interpret=``;
    set ``pallas=True`` so consumers know to thread it).  When
    ``transform_params`` is set, BOTH fns receive the transformed
    params — the hook runs once, at bind time (e.g. the engine's
    constructor), not per call.
    """

    name: str
    forward: Callable                       # (params, cfg, x, ...) -> logits
    ref: Callable                           # numerical oracle, same signature
    fused_level: str = "none"               # roofline tier (FUSED_LEVELS)
    pallas: bool = False                    # Pallas kernel: interpret= off-TPU
    compute_dtypes: tuple = ("float32", "bfloat16")
    transform_params: Callable | None = None   # params -> params (quantize, ...)
    tolerance: float = 2e-4                 # max |forward - ref| in fp32
    quantized: bool = False                 # tag: weights are sub-fp32
    weight_bytes: int | None = None         # roofline weight precision override
    per_sample_bytes: Callable | None = None   # (cfg, params) -> VMEM bytes/jet
    fallback: str | None = None             # degrade-to path (see fallback_chain)
    complexity: str = "O(N^2)"              # aggregation class (COMPLEXITY_CLASSES)
    flops_model: Callable | None = None     # (cfg, batch) -> FLOPs of one step
    residency_model: Callable | None = None  # (cfg, params, batch) -> modeled
    #   tiling/residency dict (the kernel autotuner's introspection hook,
    #   e.g. fused_jedinet.autotune.modeled_residency) — what the static
    #   kernel-contract auditor cross-checks the traced pallas_call
    #   against.  Required for pallas=True paths (the auditor flags its
    #   absence); meaningless for XLA paths.
    description: str = ""

    def __post_init__(self):
        if self.fused_level not in FUSED_LEVELS:
            raise ValueError(
                f"path {self.name!r}: fused_level {self.fused_level!r} "
                f"not in {FUSED_LEVELS}")
        if self.complexity not in COMPLEXITY_CLASSES:
            raise ValueError(
                f"path {self.name!r}: complexity {self.complexity!r} "
                f"not in {COMPLEXITY_CLASSES}")

    # -- hooks with defaults -------------------------------------------------

    def prepare_params(self, params):
        """Apply the params-transform hook (identity when none)."""
        if self.transform_params is None:
            return params
        return self.transform_params(params)

    def supports_dtype(self, compute_dtype: str) -> bool:
        return compute_dtype in self.compute_dtypes

    def bucket_bytes(self, cfg, params) -> int:
        """Per-sample VMEM working set driving the serving bucket ladder.

        Defaults to the sender-TILED whole-network kernel's estimate at
        the smallest sender tile — the deepest honest ladder, since the
        kernel-side 2D autotuner can always fall back to that tile to
        fit any rung the ladder derives from it.
        """
        if self.per_sample_bytes is not None:
            return int(self.per_sample_bytes(cfg, params))
        from repro.kernels.autotune import _SUBLANE
        from repro.kernels.fused_jedinet.autotune import (
            full_forward_tiled_bytes_per_sample, mlp_widths)
        return full_forward_tiled_bytes_per_sample(
            cfg.n_objects, cfg.n_features,
            mlp_widths(params["fr"]), mlp_widths(params["fo"]),
            mlp_widths(params["phi"]),
            block_s=min(_SUBLANE, cfg.n_objects))

    def reserved_vmem_bytes(self, cfg, params) -> int:
        """VMEM the path's weights occupy before any batch row arrives,
        at their ACTUAL serving dtype — int8-quantized params reserve
        ~4x less than fp32, which is how quantized paths earn deeper
        bucket ladders (ROADMAP "per-path quantization-aware bucket
        policy").  ``params`` must already be transformed
        (:meth:`prepare_params`)."""
        from repro.kernels.autotune import weight_vmem_bytes
        return weight_vmem_bytes(params, cfg.compute_dtype)

    def bucket_ladder(self, cfg, params, max_batch: int,
                      budget_bytes: int | None = None) -> list[int]:
        """The serving pad-to-bucket ladder this path earns: rungs from
        :func:`repro.kernels.autotune.bucket_ladder` under the path's
        OWN per-sample working set and weight-residency reservation —
        the per-path policy every consumer (engine, CLI ``--list-paths``,
        benchmarks) resolves through one call."""
        from repro.kernels import autotune
        kw = {} if budget_bytes is None else {"budget_bytes": budget_bytes}
        return autotune.bucket_ladder(
            max_batch, self.bucket_bytes(cfg, params),
            reserved_bytes=self.reserved_vmem_bytes(cfg, params), **kw)

    def flops_for(self, cfg, batch: int) -> float:
        """Modeled FLOPs of one batched forward step through this path.

        The per-path FLOPs hook: O(N) paths plug in their own model
        (``codesign.jedi_linear_flops``) so codesign/roofline reason
        about the algorithmic class, not just bytes; the default is the
        dense edge-grid model (``codesign.TPUModel.flops``)."""
        if self.flops_model is not None:
            return float(self.flops_model(cfg, batch))
        from repro.core import codesign
        return float(codesign.TPUModel.flops(cfg, batch))

    def roofline_for(self, cfg, buckets, *, compute_bytes: int = 2,
                     chips: int = 1) -> dict:
        """TPUModel roofline per bucket at this path's declared level
        (and weight precision / FLOPs model, for quantized and O(N)
        paths)."""
        from repro.core import codesign
        return codesign.bucket_roofline(
            cfg, buckets, level=self.fused_level,
            compute_bytes=compute_bytes, chips=chips,
            weight_bytes=self.weight_bytes, flops_fn=self.flops_model)

    def audit(self, cfg, params, *, max_batch: int = 1024) -> list:
        """Statically audit this path's kernel contract: trace the
        forward at every rung of its bucket ladder (abstract shapes, no
        kernel execution) and cross-check the pallas_call's grid /
        BlockSpecs / scratch / accumulator dtypes against
        :attr:`residency_model` and the VMEM budget.  Returns the list
        of findings (empty == contract holds).  ``params`` are RAW
        (untransformed) — the audit applies :meth:`prepare_params`
        itself so it sees the serving-time pytree."""
        from repro.analysis.kernel_audit import audit_path
        return audit_path(self, cfg, params, max_batch=max_batch)


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, PathSpec] = {}

# Modules that register built-in paths at import.  Imported lazily on
# first registry access, so a path lives entirely in its own module and
# still shows up in every consumer (engine, CLI, benchmarks, CI gate).
_BUILTIN_MODULES = (
    "repro.core.interaction_net",
    "repro.core.int8_path",
    "repro.core.jedi_linear_path",
)
_builtins_state = "pending"           # "pending" -> "loading" -> "done"


def _ensure_builtins() -> None:
    global _builtins_state
    if _builtins_state != "pending":  # "loading": modules re-enter via register
        return
    _builtins_state = "loading"
    try:
        for mod in _BUILTIN_MODULES:
            importlib.import_module(mod)
    except Exception:
        # don't latch a silently partial registry: the next registry
        # access retries (already-imported modules are sys.modules-cached,
        # so their register() calls don't re-run) and fails loudly again
        _builtins_state = "pending"
        raise
    _builtins_state = "done"


def register(spec: PathSpec, *, overwrite: bool = False) -> PathSpec:
    """Register a :class:`PathSpec`; returns it for chaining."""
    if not overwrite and spec.name in _REGISTRY:
        raise ValueError(f"forward path {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def register_path(name: str | None = None, **fields):
    """Decorator: register the decorated fn as a forward path.

        @register_path(name="int8_fused_full", ref=..., fused_level="full")
        def forward_int8_fused_full(params, cfg, x, *, interpret=False): ...

    ``name`` defaults to the fn's ``__name__`` with a leading
    ``forward_`` stripped.  The fn itself is returned unchanged.
    """
    def deco(fn):
        pname = name or fn.__name__.removeprefix("forward_")
        register(PathSpec(name=pname, forward=fn, **fields))
        return fn
    return deco


def get(name: str) -> PathSpec:
    """The spec for ``name``; raises ValueError listing the choices."""
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown forward path {name!r}; "
            f"available: {', '.join(sorted(_REGISTRY))}") from None


def specs(**tags: Any) -> list[PathSpec]:
    """All registered specs, sorted by name, filtered by spec fields.

    Any :class:`PathSpec` field is a filter: ``specs(quantized=True)``,
    ``specs(pallas=False, fused_level="full")``.  Unknown field names
    raise (a typo'd filter silently matching nothing is worse).
    """
    _ensure_builtins()
    for k in tags:
        if k not in PathSpec.__dataclass_fields__:
            raise ValueError(f"unknown PathSpec filter field {k!r}")
    return [s for _, s in sorted(_REGISTRY.items())
            if all(getattr(s, k) == v for k, v in tags.items())]


def available(**tags: Any) -> list[str]:
    """Names of all registered paths (sorted), filtered like :func:`specs`."""
    return [s.name for s in specs(**tags)]


def fallback_chain(name: str) -> list[str]:
    """The degradation ladder rooted at ``name``: ``[name, fallback,
    fallback-of-fallback, ...]`` down to a terminal path.

    The serving tier demotes along this chain when a rung fails (compile
    error, VMEM-fit rejection, non-finite outputs — see
    :mod:`repro.serving.resilient`), so the chain must be a safe ladder:
    every link resolves to a registered path, no cycles, and the
    terminal rung is a **non-Pallas reference path** — plain XLA cannot
    compile-fail the way a hand-written kernel can, so the bottom of the
    ladder always serves.  Raises ``ValueError`` on any violation.
    """
    chain, seen = [], set()
    cur: str | None = name
    while cur is not None:
        if cur in seen:
            raise ValueError(
                f"fallback chain of {name!r} cycles at {cur!r}: "
                f"{' -> '.join(chain + [cur])}")
        spec = get(cur)        # raises listing choices on unknown links
        chain.append(cur)
        seen.add(cur)
        cur = spec.fallback
    terminal = get(chain[-1])
    if terminal.pallas:
        raise ValueError(
            f"fallback chain of {name!r} terminates in Pallas path "
            f"{terminal.name!r} ({' -> '.join(chain)}); chains must end "
            "in a non-Pallas reference path so the degradation ladder "
            "always has a servable bottom rung")
    return chain


def terminal_rung(name: str) -> str:
    """The non-Pallas reference path at the bottom of ``name``'s
    fallback chain — the rung the sentinel's shadow re-execution trusts
    as its online oracle (:mod:`repro.serving.sentinel`), and the one
    :func:`fallback_chain` guarantees always serves."""
    return fallback_chain(name)[-1]


def validate_fallbacks() -> dict[str, list[str]]:
    """Resolve every registered path's fallback chain; raises on the
    first broken one (unknown link, cycle, or Pallas terminal).  Returns
    ``{name: chain}`` — the registry-wide degradation map."""
    return {name: fallback_chain(name) for name in available()}


def describe(names: Sequence[str] | None = None, *, cfg=None, params=None,
             max_batch: int = 1024) -> str:
    """Human-readable registry table (the CLI's ``--list-paths``).

    The static columns (fusion level, kernel kind, compute dtypes,
    roofline ``wB`` = weight bytes, tolerance) always print.  Given a
    ``cfg`` AND raw ``params``, each path's RESOLVED bucket policy is
    appended — per-sample VMEM bytes, weight-residency reservation and
    the bucket ladder it earns for ``max_batch`` — so an operator can
    see directly why a quantized path (smaller reservation) gets a
    deeper ladder than its fp32 twin.
    """
    rows = [get(n) for n in (names if names is not None else available())]
    lines = [f"{'path':<22} {'level':<5} {'cmplx':<6} {'kernel':<7} "
             f"{'dtypes':<18} {'wB':<3} {'tol':<7} "
             f"{'fallback chain':<34} description"]
    for s in rows:
        kind = "pallas" if s.pallas else "xla"
        if s.quantized:
            kind += "+q"
        wb = "-" if s.weight_bytes is None else str(s.weight_bytes)
        try:
            chain = fallback_chain(s.name)
            fb = ">".join(chain[1:]) if len(chain) > 1 else "-"
        except ValueError as e:          # surface broken chains, don't crash
            fb = f"!invalid ({e})"
        lines.append(
            f"{s.name:<22} {s.fused_level:<5} {s.complexity:<6} {kind:<7} "
            f"{','.join(s.compute_dtypes):<18} {wb:<3} {s.tolerance:<7.0e} "
            f"{fb:<34} {s.description}")
    if cfg is not None and params is not None:
        from repro.core.codesign import path_bucket_policy
        lines.append("")
        lines.append(f"bucket policy @ n_objects={cfg.n_objects} "
                     f"max_batch={max_batch} (per-path VMEM model):")
        lines.append(f"{'path':<22} {'B/sample':>9} {'reservedB':>10} ladder")
        for s in rows:
            pol = path_bucket_policy(s, cfg, params, max_batch=max_batch,
                                     roofline=False)
            lines.append(
                f"{s.name:<22} {pol['per_sample_bytes']:>9} "
                f"{pol['reserved_vmem_bytes']:>10} "
                f"{','.join(str(b) for b in pol['bucket_ladder'])}")
    return "\n".join(lines)
