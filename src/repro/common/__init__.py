from repro.common import tree
from repro.common.tree import (
    param_count,
    param_bytes,
    tree_cast,
    global_norm,
    path_map,
)

__all__ = ["tree", "param_count", "param_bytes", "tree_cast", "global_norm", "path_map"]
