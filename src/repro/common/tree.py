"""Pytree utilities shared across the framework.

All model parameters in this codebase are plain nested dicts of jnp arrays
(no flax/haiku dependency — the substrate is built from scratch per the
project brief).  These helpers provide the common operations a production
trainer needs: counting, casting, norm computation and path-aware mapping
(used by the sharding rules and the optimizer's per-parameter labels).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def param_count(tree: Pytree) -> int:
    """Total number of scalar parameters in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def param_bytes(tree: Pytree) -> int:
    """Total bytes occupied by a pytree (using each leaf's dtype)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        if hasattr(l, "shape"):
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def tree_cast(tree: Pytree, dtype) -> Pytree:
    """Cast every floating-point leaf of a pytree to `dtype`."""

    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


def global_norm(tree: Pytree) -> jax.Array:
    """L2 norm over all leaves (used for grad clipping)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def path_str(path) -> str:
    """Render a jax KeyPath as 'a/b/0/c'."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def path_map(fn: Callable[[str, Any], Any], tree: Pytree) -> Pytree:
    """Map `fn(path_string, leaf) -> leaf` over a pytree."""
    return jax.tree_util.tree_map_with_path(lambda p, l: fn(path_str(p), l), tree)


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree: Pytree, s) -> Pytree:
    return jax.tree_util.tree_map(lambda x: x * s, tree)
