"""Trigger-tier CLI driver: the logic behind ``repro.launch.trigger_serve``.

The launch module is deliberately a THIN shell — argparse plus one call
in here (``tests/test_thin_cli.py`` enforces that with an AST guard) —
so every behavior an operator reaches from the command line lives
inside the serving package where the event loop, the resilience ladder
and the benchmarks can reuse it:

* :func:`make_stream` — synthetic event stream, fully materialized so
  generation stays off the timed path;
* :func:`run_trigger_cli` — the whole serve flow: registry listing,
  fault drills through the guarded per-request path, the double-
  buffered stream run with roofline context, and the health report;
* :func:`print_health` — the health state machine's operator view.

Output formats are part of the CLI contract (tests assert on them);
change them here, not in the launch shell.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import paths
from repro.core.interaction_net import JediNetConfig, init
from repro.data.jets import make_jets
from repro.serving.faults import SILENT_SEAMS, FaultInjector
from repro.serving.resilient import ResilientEngine
from repro.serving.sentinel import SentinelConfig


def make_stream(rng, n_batches: int, batch: int, n_objects: int,
                n_features: int):
    """Pre-generated synthetic event stream, fully materialized so the
    per-jet numpy generation loop stays OFF the timed serving path — the
    latencies below must measure transfer+compute, not the generator."""
    return [make_jets(rng, batch, n_objects, n_features)[0]
            for _ in range(n_batches)]


def print_health(engine) -> None:
    """The health state machine's operator view (``--health``)."""
    h = engine.health()
    print(f"[health] state={h['state']} base={h['base_path']} "
          f"chain={'>'.join(h['chain'])} inflight={h['inflight']}")
    for bucket, st in h["buckets"].items():
        probe = ("-" if st["next_probe_in_s"] is None
                 else f"{st['next_probe_in_s']:.2f}s")
        quarantine = ""
        if st.get("quarantined"):
            quarantine = (f" QUARANTINED[{st['quarantined_path']}] "
                          f"clean_canaries={st['clean_canaries']}")
        print(f"  bucket {bucket:>5}: path={st['path']} level={st['level']} "
              f"demotions={st['demotions']} next_probe_in={probe}"
              f"{quarantine}{' DOWN' if st['down'] else ''}")
    if h.get("sentinel"):
        s = h["sentinel"]
        print(f"  sentinel: canary_every={s['canary_every']} "
              f"shadow_rate={s['shadow_rate']:g} "
              f"promote_after={s['promote_after']}")
    if h["counters"]:
        print("  counters: " + " ".join(f"{k}={v}"
                                        for k, v in h["counters"].items()))
    else:
        print("  counters: (none)")
    if h.get("gauges"):
        print("  gauges:   " + " ".join(f"{k}={v:g}"
                                        for k, v in h["gauges"].items()))


def parse_drills(specs, injector, path) -> None:
    """Arm ``SEAM[:TIMES[:MAGNITUDE]]`` drill specs against ``path``.

    The third field is seam-dependent: a delay in seconds for the timed
    loud seams (``latency``, ``stuck``), a corruption factor for the
    silent seams (``scale_drift``, ``weight_corrupt``)."""
    for spec in specs:
        parts = spec.split(":")
        seam = parts[0]
        times = float(parts[1]) if len(parts) > 1 else 1.0
        if seam in SILENT_SEAMS:
            factor = float(parts[2]) if len(parts) > 2 else 4.0
            injector.arm(seam, path=path, times=times, factor=factor)
        else:
            delay = float(parts[2]) if len(parts) > 2 else 0.05
            injector.arm(seam, path=path, times=times, delay_s=delay)


def build_trigger_cli(ap) -> None:
    """Install the trigger-serve arguments on an ``argparse`` parser."""
    ap.add_argument("--n-objects", type=int, default=30)
    ap.add_argument("--n-features", type=int, default=16)
    ap.add_argument("--batch", type=int, default=256,
                    help="events per stream tick (the trigger's time slice)")
    ap.add_argument("--batches", type=int, default=40)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--forward", default="fused_full",
                    choices=paths.available())
    ap.add_argument("--compute-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--interpret", action="store_true",
                    help="force Pallas interpret mode (auto-enabled off-TPU)")
    ap.add_argument("--list-paths", action="store_true",
                    help="print the forward-path registry and exit")
    ap.add_argument("--health", action="store_true",
                    help="print the engine health report after the run")
    ap.add_argument("--drill", action="append", default=None,
                    metavar="SEAM[:TIMES[:MAGNITUDE]]",
                    help="arm a fault against the primary path (repeatable) "
                         "and serve through the guarded per-request path. "
                         "Loud seams: compile, dispatch, input_nan, "
                         "output_nan, latency, stuck (MAGNITUDE = delay "
                         "seconds).  Silent seams: scale_drift, "
                         "weight_corrupt, stale_cache (MAGNITUDE = "
                         "corruption factor) — pair them with --sentinel "
                         "or they serve wrong answers undetected")
    ap.add_argument("--sentinel", action="store_true",
                    help="arm the silent-corruption sentinel: golden "
                         "canaries, terminal-rung shadow re-execution, "
                         "canary-gated quarantine (see --health)")
    ap.add_argument("--shadow-rate", type=float, default=1 / 16,
                    help="sentinel shadow re-execution duty cycle "
                         "(fraction of live requests; 0 disables shadows)")
    ap.add_argument("--canary-every", type=int, default=16,
                    help="sentinel canary cadence in requests per bucket")
    ap.add_argument("--watchdog-s", type=float, default=30.0,
                    help="stuck-dispatch watchdog budget")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-tick serve deadline (drill path); expired "
                         "ticks are shed, not dispatched")
    ap.add_argument("--seed", type=int, default=0)


def run_trigger_cli(args) -> None:
    """Serve a synthetic stream per parsed ``args`` and print the report."""
    if args.list_paths:
        # Registry table PLUS each path's resolved bucket policy (per-
        # sample VMEM model, weight residency, the ladder it earns) for
        # this CLI's config — the operator-facing answer to "why does
        # the quantized path get deeper buckets than fp32?".
        cfg = JediNetConfig(n_objects=args.n_objects,
                            n_features=args.n_features,
                            compute_dtype=args.compute_dtype)
        params = init(jax.random.PRNGKey(args.seed), cfg)
        print(paths.describe(cfg=cfg, params=params,
                             max_batch=max(args.batch, 1)))
        return

    cfg = JediNetConfig(n_objects=args.n_objects, n_features=args.n_features,
                        compute_dtype=args.compute_dtype)
    params = init(jax.random.PRNGKey(args.seed), cfg)
    injector = None
    if args.drill:
        injector = FaultInjector()
        parse_drills(args.drill, injector, args.forward)
    sentinel = None
    if getattr(args, "sentinel", False):
        # sync shadows: the CLI's verdict (quarantines= in --health)
        # must be complete when the run prints, not racing a worker
        sentinel = SentinelConfig(canary_every=args.canary_every,
                                  shadow_rate=args.shadow_rate,
                                  shadow_sync=True)
    engine = ResilientEngine(params, cfg, forward=args.forward,
                             interpret=args.interpret or None,
                             max_batch=max(args.batch, 1),
                             injector=injector,
                             watchdog_s=args.watchdog_s,
                             sentinel=sentinel)

    rng = np.random.RandomState(args.seed)
    stream = make_stream(rng, args.batches, args.batch, args.n_objects,
                         args.n_features)

    if args.drill:
        # guarded per-request path: every batch rides the full ladder —
        # NaN detection, watchdog, shedding — so injected faults are
        # absorbed, counted, and visible in --health, never raised.
        served = shed = 0
        t0 = time.perf_counter()
        for tick in stream:
            deadline = (None if args.deadline_ms is None
                        else engine._clock() + args.deadline_ms * 1e-3)
            out = engine.infer(tick, deadline=deadline)
            if out is None:
                shed += 1
            else:
                served += 1
        wall = time.perf_counter() - t0
        snap = engine.metrics.snapshot()
        print(f"[trigger_serve] DRILL forward={args.forward} "
              f"faults={','.join(args.drill)} ticks={args.batches} "
              f"served={served} shed={shed} wall={wall:.3f}s")
        print(f"  latency    p50 {snap['p50_us']:8.1f} us   "
              f"p99 {snap['p99_us']:8.1f} us  per batch")
        print_health(engine)
        return

    res = engine.run_stream(stream, warmup=args.warmup)

    if not res["latencies"]:
        print("[trigger_serve] stream too short for stats "
              f"(need > warmup={args.warmup} batches, got {args.batches})")
        if args.health:
            print_health(engine)
        return

    snap = engine.metrics.snapshot()
    bucket = res["bucket"]
    model = engine.roofline([bucket])[bucket]

    print(f"[trigger_serve] forward={args.forward} "
          f"n_objects={args.n_objects} batch={args.batch} bucket={bucket} "
          f"dtype={args.compute_dtype} shards={engine.n_shards}")
    print(f"  sustained  {snap['kgps']:8.1f} KGPS  "
          f"({res['events']} events / {res['wall_s']:.3f} s)")
    print(f"  latency    p50 {snap['p50_us']:8.1f} us   "
          f"p99 {snap['p99_us']:8.1f} us  per batch")
    print(f"  per-event  p50 {snap['per_event_p50_us']:8.3f} us")
    print(f"  roofline   modeled {model['step_us']:.1f} us/step "
          f"({model['bound']}-bound, {model['hbm_bytes'] / 1e6:.2f} MB HBM, "
          f"level={model['fused_level']})")
    print(f"  serving    path={engine.active_path(bucket)} "
          f"(chain {'>'.join(engine.chain)})")
    if args.health:
        print_health(engine)
