"""Rolling serving metrics: p50/p99 batch latency + sustained KGPS.

One accounting surface shared by the engine, the trigger CLI and
``benchmarks/bench_serving.py`` so every consumer reports the same
numbers the same way:

* latencies are *per dispatched batch*, measured host-handoff ->
  logits-ready (what the double-buffered feed loop observes);
* events are the VALID (un-padded) events in the batch — padding rows
  added to reach a compile bucket never inflate throughput;
* KGPS (thousand graphs = events per second) is events / wall over the
  post-warmup stream, not the sum of latencies — with double buffering
  the pipeline sustains more than 1/latency batches per second.
* fault-tolerance events (shed requests, path demotions/re-promotions,
  watchdog timeouts, non-finite batches, ...) land in monotonic named
  COUNTERS (:meth:`ServingMetrics.incr`) — the health state machine
  (:mod:`repro.serving.resilient`) and ``trigger_serve --health`` read
  them off the same snapshot as the latency percentiles.
* instantaneous levels (queue depth, in-flight dispatches, free decode
  slots, ...) land in GAUGES (:meth:`ServingMetrics.gauge`) — set, not
  summed — so the event loop and the LM slot scheduler surface their
  current occupancy in the same ``snapshot()`` / ``--health`` report as
  the monotonic counters; each gauge also remembers its high-water mark
  (``<name>_max``), which is what backlog tests and capacity planning
  actually read.
* counter and gauge mutation is LOCKED: the sentinel's shadow worker
  (:mod:`repro.serving.sentinel`) increments from its own thread while
  the serve thread records batches — ``Counter.__iadd__`` is a
  read-modify-write, and a lost ``shadow_disagreements`` increment is a
  lost corruption signal.
"""

from __future__ import annotations

import collections
import dataclasses
import threading

import numpy as np


def percentile(xs, q: float) -> float:
    """float percentile of a sequence (empty -> nan)."""
    if len(xs) == 0:
        return float("nan")
    return float(np.percentile(np.asarray(xs), q))


def kgps(events: int, wall_s: float) -> float:
    """Sustained thousand-events-per-second (nan when wall is degenerate)."""
    return events / wall_s / 1e3 if wall_s > 0 else float("nan")


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    latency_s: float
    events: int          # valid events (padding excluded)
    bucket: int          # compile-bucket batch size the events rode in


class ServingMetrics:
    """Rolling window of per-batch records with percentile / KGPS views."""

    def __init__(self, window: int = 4096):
        self._records: collections.deque[BatchRecord] = collections.deque(
            maxlen=window)
        self._wall_s = 0.0       # accumulated post-warmup stream wall time
        self._wall_events = 0    # valid events covered by _wall_s
        self._counters: collections.Counter[str] = collections.Counter()
        self._gauges: dict[str, float] = {}
        self._gauge_peaks: dict[str, float] = {}
        self._lock = threading.Lock()

    def record_batch(self, latency_s: float, events: int, bucket: int) -> None:
        self._records.append(BatchRecord(latency_s, events, bucket))

    def incr(self, name: str, n: int = 1) -> None:
        """Bump a monotonic named counter (shed / demotion / timeout /
        ... — the fault-tolerance layer's accounting surface).
        Thread-safe: shadow-verification threads increment concurrently
        with the serve thread."""
        with self._lock:
            self._counters[name] += n

    def counter(self, name: str) -> int:
        return self._counters[name]

    @property
    def counters(self) -> dict:
        """Copy of all non-zero counters (stable for snapshotting)."""
        return {k: v for k, v in sorted(self._counters.items()) if v}

    def gauge(self, name: str, value: float) -> None:
        """Set an instantaneous level (queue depth, inflight count, free
        slots, ...).  Unlike :meth:`incr` the value REPLACES the previous
        one; the high-water mark is tracked alongside as ``<name>_max``."""
        value = float(value)
        with self._lock:
            self._gauges[name] = value
            peak = self._gauge_peaks.get(name)
            if peak is None or value > peak:
                self._gauge_peaks[name] = value

    def gauge_value(self, name: str, default: float = 0.0) -> float:
        return self._gauges.get(name, default)

    def gauge_max(self, name: str, default: float = 0.0) -> float:
        """High-water mark of ``name`` since this metrics object was
        created (backlog tests / capacity planning read this)."""
        return self._gauge_peaks.get(name, default)

    @property
    def gauges(self) -> dict:
        """Copy of the current gauge levels (stable for snapshotting)."""
        return dict(sorted(self._gauges.items()))

    def record_wall(self, wall_s: float, events: int) -> None:
        """Fold a measured stream segment into the sustained-KGPS estimate."""
        self._wall_s += wall_s
        self._wall_events += events

    @property
    def batches(self) -> int:
        return len(self._records)

    @property
    def events(self) -> int:
        return sum(r.events for r in self._records)

    def latencies_s(self) -> list[float]:
        return [r.latency_s for r in self._records]

    def snapshot(self) -> dict:
        """One dict with everything the CLI / benchmark prints."""
        lats = self.latencies_s()
        evs = [r.events for r in self._records]
        mean_events = float(np.mean(evs)) if evs else float("nan")
        p50_us = percentile(lats, 50) * 1e6
        p99_us = percentile(lats, 99) * 1e6
        return {
            "batches": self.batches,
            "events": self.events,
            "p50_us": p50_us,
            "p99_us": p99_us,
            "per_event_p50_us": p50_us / mean_events if evs else float("nan"),
            "per_event_p99_us": p99_us / mean_events if evs else float("nan"),
            "kgps": kgps(self._wall_events, self._wall_s),
            "buckets": sorted({r.bucket for r in self._records}),
            "counters": self.counters,
            "gauges": self.gauges,
        }
