"""LM continuous batching on the serving fabric: prefill + slot decode.

The LM serving driver (``repro.launch.serve``) used to be a standalone
script: its own compile caching (none — it re-traced prefill for every
new prompt length), its own scheduling loop, no metrics, no deadlines,
no fault seams.  This module re-plants it on the shared fabric
(:mod:`repro.serving.core`), so the slot-recycling decode loop gets for
free exactly what the trigger engine already has:

* **bucketed prefill** — prompts are right-padded up a power-of-two
  length ladder, so mixed-length requests share a handful of prefill
  compilations instead of one trace per distinct prompt length.  With
  causal attention the pad positions cannot influence positions before
  them, so the spliced ``[:pl]`` cache slice and the ``pl - 1`` logits
  row are exactly what the unpadded prefill would have produced.
* **warm compile cache + fault seams** — prefill and decode callables
  live in the :class:`~repro.serving.core.ExecutionCore` cache under
  ``("lm", L)`` / ``("lm", "decode")`` keys; a
  :class:`~repro.serving.faults.FaultInjector` can target the compile
  and dispatch seams by ``path="lm"`` like any trigger path.
* **metrics, deadlines, health** — decode steps land in the shared
  :class:`~repro.serving.metrics.ServingMetrics` (per-step latency
  percentiles, sustained tokens/s over the wall-union), queued requests
  carry serve-by deadlines that shed instead of admitting late, and
  ``health()`` reports the same state machine vocabulary as the trigger
  tier (``healthy`` / ``shedding``) plus slot-occupancy gauges.

Scheduling is IDENTICAL to the pre-fabric driver — admit free slots
FIFO in slot order before each decode step, one token per active slot
per step, retire at ``max_new`` and recycle the slot — so greedy token
streams reproduce the old ``launch/serve.py`` output exactly
(``tests/test_loop.py`` pins this).
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.core import ExecutionCore, Workload
from repro.serving.metrics import ServingMetrics


@dataclasses.dataclass
class LMRequest:
    """One generation request: prompt in, greedy continuation out."""
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    t_deadline: float | None = None     # absolute admit-by time (clock base)
    shed: bool = False


def prompt_bucket_ladder(max_len: int, *, start: int = 16) -> list[int]:
    """Power-of-two prompt-length ladder up to (and capped at) ``max_len``.

    Same discipline as the trigger's batch ladder: any prompt length
    pads up to the next rung, so L distinct lengths cost O(log L)
    prefill compilations instead of L.
    """
    if max_len < 1:
        raise ValueError(f"max_len must be >= 1, got {max_len}")
    ladder, b = [], max(1, start)
    while b < max_len:
        ladder.append(b)
        b *= 2
    ladder.append(max_len)
    return ladder


class LMWorkload(Workload):
    """Transformer prefill/decode as a fabric :class:`Workload`.

    Buckets are PROMPT LENGTHS (ints) for prefill, plus the sentinel
    ``"decode"`` for the slot-batched decode step — both flow through
    :meth:`~repro.serving.core.ExecutionCore.compiled_for`'s cache and
    compile fault seam under ``path="lm"``.
    """

    name = "lm"

    def __init__(self, params, cfg, *, slots: int, max_seq: int):
        from repro.models import transformer as tfm
        self._tfm = tfm
        self.params = params
        self.cfg = cfg
        self.slots = int(slots)
        self.max_seq = int(max_seq)

    def bucket_ladder(self, max_batch: int) -> list[int]:
        # ``max_batch`` is the longest admissible prompt here
        return prompt_bucket_ladder(min(max_batch, self.max_seq))

    def cache_key(self, bucket) -> tuple:
        c = self.cfg
        return (self.name, bucket, c.n_layers, c.d_model, c.n_heads,
                c.n_kv_heads, c.vocab_size, c.compute_dtype)

    def build(self, bucket):
        tfm, cfg = self._tfm, self.cfg
        if bucket == "decode":
            def dec(params, cache, toks):
                return tfm.decode_step(params, cfg, cache, toks)
            return jax.jit(functools.partial(dec, self.params))

        def pre(params, toks):                     # prefill at padded length
            return tfm.forward(params, cfg, toks, return_cache=True)
        return jax.jit(functools.partial(pre, self.params))

    def placeholder(self, bucket: int) -> np.ndarray:
        return np.zeros((1, int(bucket)), np.int32)


class LMEngine(ExecutionCore):
    """Slot-recycling continuous-batching LM server on the fabric.

    ``submit()`` enqueues requests; ``step()`` is one scheduler tick
    (admit free slots, one batched decode step); ``run()`` drains to
    completion.  The decode cache is batched over ``slots`` concurrent
    requests; a finished request releases its slot to the next queued
    one mid-stream (continuous batching), exactly as the pre-fabric
    ``launch/serve.py`` loop did.
    """

    def __init__(self, params, cfg, *, slots: int = 4, max_seq: int = 128,
                 prompt_buckets=None, metrics: ServingMetrics | None = None,
                 injector=None, clock=time.monotonic):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        super().__init__(
            LMWorkload(params, cfg, slots=slots, max_seq=max_seq),
            bucket_sizes=prompt_buckets, max_batch=max_seq,
            metrics=metrics, injector=injector)
        self._clock = clock
        self.slots = int(slots)
        self.max_seq = int(max_seq)
        self.cache = self.workload._tfm.init_cache(cfg, slots, max_seq)
        self.slot_req: list[LMRequest | None] = [None] * slots
        self.queue: list[LMRequest] = []
        self.done: list[LMRequest] = []
        self._next_rid = 0
        self._last_shed: float | None = None
        self.shed_window_s = 5.0

    # -- request flow -------------------------------------------------------

    def submit(self, prompt, max_new: int, *,
               deadline_s: float | None = None) -> LMRequest:
        """Enqueue one request; it admits when a slot frees up.  With a
        ``deadline_s`` budget the request is SHED (never admitted,
        ``shed=True``, empty ``out``) if it is still queued past it."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.shape[0] < 1:
            raise ValueError("empty prompt")
        if prompt.shape[0] > self.max_seq:
            raise ValueError(
                f"prompt length {prompt.shape[0]} exceeds max_seq "
                f"{self.max_seq}")
        req = LMRequest(self._next_rid, prompt, int(max_new))
        if deadline_s is not None:
            req.t_deadline = self._clock() + deadline_s
        self._next_rid += 1
        self.queue.append(req)
        self.metrics.incr("lm_requests")
        self._update_gauges()
        return req

    def warm(self, buckets=None) -> None:
        """Pre-compile the prefill ladder AND the decode step (against a
        throwaway cache, so the live one is untouched)."""
        super().warm(buckets)
        throwaway = self.workload._tfm.init_cache(
            self.workload.cfg, self.slots, self.max_seq)
        toks = jnp.zeros((self.slots,), jnp.int32)
        jax.block_until_ready(self.compiled_for("decode")(throwaway, toks))

    def step(self) -> bool:
        """One scheduler tick: admit free slots from the queue (shedding
        expired requests), then one batched decode step.  Returns True
        while work remains."""
        now = self._clock()
        for s in range(self.slots):
            if self.slot_req[s] is not None:
                continue
            while self.queue:
                req = self.queue.pop(0)
                if req.t_deadline is not None and now >= req.t_deadline:
                    self._shed(req)
                    continue
                self._admit(s, req)
                break
        if not any(r is not None for r in self.slot_req):
            self._update_gauges()
            return bool(self.queue)
        if self.injector is not None:
            self.injector.check("dispatch", path=self.workload.name,
                                bucket="decode")
        toks = jnp.asarray([
            (self.slot_req[s].out[-1] if self.slot_req[s] else 0)
            for s in range(self.slots)], jnp.int32)
        decode = self.compiled_for("decode")
        t0 = time.perf_counter()
        logits, self.cache = decode(self.cache, toks)
        nxt = np.asarray(jnp.argmax(logits, -1))
        t1 = time.perf_counter()
        active = sum(1 for r in self.slot_req if r is not None)
        self.metrics.record_batch(t1 - t0, active, self.slots)
        self._record_wall_window(t0, t1, active)
        self.metrics.incr("decode_steps")
        self.metrics.incr("tokens_emitted", active)
        for s in range(self.slots):
            req = self.slot_req[s]
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            if len(req.out) >= req.max_new:
                self.done.append(req)
                self.slot_req[s] = None           # release slot
        self._update_gauges()
        return bool(self.queue or any(r is not None for r in self.slot_req))

    def run(self) -> dict:
        """Drain the queue to completion; returns the serve report."""
        t0 = time.perf_counter()
        while self.step():
            pass
        dt = time.perf_counter() - t0
        steps = self.metrics.counter("decode_steps")
        return {
            "done": sorted(self.done, key=lambda r: r.rid),
            "steps": steps,
            "wall_s": dt,
            "steps_per_s": steps / dt if dt > 0 else float("nan"),
            "shed": self.metrics.counter("lm_shed_requests"),
            "prefill_compiles": sum(
                1 for k in self._cache if k[1] != "decode"),
            "snapshot": self.metrics.snapshot(),
        }

    # -- internals ----------------------------------------------------------

    def _admit(self, slot: int, req: LMRequest) -> None:
        """Prefill one request (padded up the prompt ladder) and splice
        its ``[:pl]`` cache slice into the batch slot."""
        pl = int(req.prompt.shape[0])
        bucket = self.bucket_for(pl)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :pl] = req.prompt
        prefill = self.compiled_for(bucket)
        t0 = time.perf_counter()
        logits, _, pc = prefill(jnp.asarray(toks))
        # causal attention: positions < pl never see the pad tail, so
        # this slice and the pl-1 logits row match the unpadded prefill
        t = self.cache["k"].shape[2]
        for key in ("k", "v"):
            upd = jnp.zeros_like(self.cache[key][:, slot])
            upd = upd.at[:, :pl].set(pc[key][:, 0, :pl])
            self.cache[key] = self.cache[key].at[:, slot].set(upd)
        sp = jnp.full((t,), -1, jnp.int32).at[:pl].set(jnp.arange(pl))
        self.cache["slot_pos"] = self.cache["slot_pos"].at[slot].set(sp)
        self.cache["pos"] = self.cache["pos"].at[slot].set(pl)
        first = int(jnp.argmax(logits[0, pl - 1]))
        t1 = time.perf_counter()
        self.metrics.record_batch(t1 - t0, 1, bucket)
        self._record_wall_window(t0, t1, 1)
        self.metrics.incr("prefills")
        self.metrics.incr("tokens_emitted")
        req.out.append(first)
        self.slot_req[slot] = req

    def _shed(self, req: LMRequest) -> None:
        req.shed = True
        self.done.append(req)
        self.metrics.incr("lm_shed_requests")
        self._last_shed = self._clock()

    def _update_gauges(self) -> None:
        self.metrics.gauge("queue_depth", len(self.queue))
        self.metrics.gauge(
            "free_slots", sum(1 for r in self.slot_req if r is None))

    # -- health -------------------------------------------------------------

    def health(self) -> dict:
        """Same vocabulary as the trigger tier's health report: a state
        plus the counters/gauges it was derived from."""
        now = self._clock()
        shedding = (self._last_shed is not None
                    and now - self._last_shed < self.shed_window_s)
        return {
            "state": "shedding" if shedding else "healthy",
            "slots": self.slots,
            "free_slots": sum(1 for r in self.slot_req if r is None),
            "queue_depth": len(self.queue),
            "counters": self.metrics.counters,
            "gauges": self.metrics.gauges,
        }


# -- CLI driver (the thin repro.launch.serve front-end calls this) ----------


def tiny_config(cfg):
    """Shrink an arch config to a 2-layer miniature (same code path)."""
    return dataclasses.replace(
        cfg, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, compute_dtype="float32", remat="none")


def build_lm_cli(ap) -> None:
    """Install the LM serve arguments on an ``argparse`` parser."""
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request admit-by budget; late requests shed")
    ap.add_argument("--health", action="store_true",
                    help="print the engine health report after the drain")


def run_lm_cli(args) -> dict:
    """Serve ``--requests`` synthetic prompts through :class:`LMEngine`
    and print the classic ``[serve]`` report (token streams unchanged
    from the pre-fabric driver)."""
    from repro.configs.registry import get_arch

    arch = get_arch(args.arch)
    assert arch.family == "lm", "serve driver is for LM archs"
    cfg = tiny_config(arch.model) if args.tiny else arch.model

    rng = np.random.RandomState(0)
    from repro.models import transformer as tfm
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    v = cfg.vocab_size

    engine = LMEngine(params, cfg, slots=args.slots, max_seq=args.max_seq)
    deadline_s = (args.deadline_ms * 1e-3
                  if args.deadline_ms is not None else None)
    for _ in range(args.requests):
        engine.submit(rng.randint(0, v, args.prompt_len), args.max_new,
                      deadline_s=deadline_s)
    report = engine.run()

    done = [r for r in report["done"] if not r.shed]
    print(f"[serve] {len(done)} requests, {report['steps']} decode steps, "
          f"{report['steps_per_s']:.1f} steps/s")
    print(f"[serve] prefill compiles: {report['prefill_compiles']}  "
          f"prompt buckets: {engine.bucket_sizes}  "
          f"shed: {report['shed']}")
    for r in done[:4]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    if args.health:
        h = engine.health()
        print(f"[health] state={h['state']} free_slots={h['free_slots']} "
              f"queue_depth={h['queue_depth']}")
        for name in sorted(h["counters"]):
            print(f"  counter {name}={h['counters'][name]}")
    return report
