"""Deadline-aware micro-batcher: requests -> pad-to-bucket batch plans.

The trigger tier receives many small requests (an event, a handful of
events) and must answer each within a latency budget.  Dispatching every
request alone wastes the accelerator; waiting for a full batch blows the
budget on quiet links.  The batcher resolves the tension the way every
production serving stack does — accumulate, flush on whichever comes
first:

* **full bucket** — pending events reach the largest compile bucket;
* **deadline** — the OLDEST pending request has waited ``deadline_s``.

Bucket sizes come from the VMEM working-set autotuner
(:func:`repro.kernels.autotune.bucket_ladder`), so a deadline flush pads
to the nearest ladder rung: the engine's warm compile cache is hit and
padding can never force a tile-degenerate recompile (every rung is
either budget-whole or an exact tile multiple).

The batcher is pure planning — no jax, no clocks of its own (``clock``
is injectable for deterministic tests).  The engine executes the plans.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """One flushed batch: concatenated valid events + reassembly map."""

    x: np.ndarray                       # (n_valid, N_o, P) — engine pads
    bucket: int                         # ladder rung to pad/compile to
    requests: tuple                     # ((rid, start, stop), ...) into x
    oldest_wait_s: float                # age of the oldest request at flush
    reason: str                         # "full" | "deadline" | "forced"
    #: Absolute per-request deadlines (batcher clock), aligned 1:1 with
    #: ``requests``; ``None`` where the request declared none.  The
    #: resilient engine sheds segments already past their deadline
    #: instead of dispatching them.
    deadlines: tuple = ()

    @property
    def n_valid(self) -> int:
        return int(self.x.shape[0])

    def deadline_for(self, i: int) -> float | None:
        """Deadline of ``requests[i]`` (None for legacy 5-field plans)."""
        return self.deadlines[i] if i < len(self.deadlines) else None


@dataclasses.dataclass
class _Pending:
    rid: int
    x: np.ndarray
    t_submit: float
    t_deadline: float | None = None     # absolute serve-by time, if any


class DeadlineBatcher:
    """Accumulate requests into bucket-sized batches under a deadline."""

    def __init__(self, bucket_sizes, *, deadline_s: float = 2e-3,
                 clock=time.monotonic):
        if not bucket_sizes:
            raise ValueError("need at least one bucket size")
        self.bucket_sizes = sorted(int(b) for b in bucket_sizes)
        self.deadline_s = float(deadline_s)
        self._clock = clock
        self._pending: list[_Pending] = []

    # -- introspection ------------------------------------------------------

    @property
    def pending_events(self) -> int:
        return sum(p.x.shape[0] for p in self._pending)

    @property
    def pending_requests(self) -> int:
        return len(self._pending)

    def bucket_for(self, n_events: int) -> int:
        """Smallest ladder rung holding ``n_events`` (largest if none do)."""
        from repro.kernels.autotune import bucket_for
        return bucket_for(self.bucket_sizes, n_events)

    # -- request flow -------------------------------------------------------

    def submit(self, rid: int, x: np.ndarray, *, now: float | None = None,
               deadline_s: float | None = None) -> list[BatchPlan]:
        """Enqueue one request of ``x.shape[0]`` events.

        Returns the batch plans this submission made ready (full-bucket
        flushes); empty list while the batch is still filling.

        ``deadline_s`` is the request's serve-by budget relative to
        ``now``; it rides through the flushed plan (absolute time, same
        clock) so the engine can shed it once expired instead of
        spending accelerator time on an answer nobody is waiting for.
        """
        if x.ndim < 1 or x.shape[0] == 0:
            raise ValueError("request must carry at least one event")
        now = self._clock() if now is None else now
        t_deadline = None if deadline_s is None else now + deadline_s
        self._pending.append(_Pending(rid=rid, x=np.asarray(x), t_submit=now,
                                      t_deadline=t_deadline))
        plans = []
        while self.pending_events >= self.bucket_sizes[-1]:
            plans.append(self._cut(self.bucket_sizes[-1], now, "full"))
        return plans

    def poll(self, *, now: float | None = None) -> list[BatchPlan]:
        """Deadline check: flush everything pending once the oldest request
        has waited ``deadline_s`` (the whole backlog goes — leaving younger
        events behind would just re-arm an already-burning fuse)."""
        if not self._pending:
            return []
        now = self._clock() if now is None else now
        if now - self._pending[0].t_submit < self.deadline_s:
            return []
        return self._drain(now, "deadline")

    def flush(self, *, now: float | None = None) -> list[BatchPlan]:
        """Force out everything pending (shutdown / end of stream)."""
        now = self._clock() if now is None else now
        return self._drain(now, "forced")

    # -- internals ----------------------------------------------------------

    def _drain(self, now: float, reason: str) -> list[BatchPlan]:
        plans = []
        while self.pending_events > self.bucket_sizes[-1]:
            plans.append(self._cut(self.bucket_sizes[-1], now, reason))
        if self._pending:
            plans.append(self._cut(self.pending_events, now, reason))
        return plans

    def _cut(self, n_events: int, now: float, reason: str) -> BatchPlan:
        """Pop up to ``n_events`` events off the queue head into one plan.

        Requests are split across plans when they straddle the cut — each
        (rid, start, stop) segment maps output rows back to its request.
        """
        parts, segments, deadlines = [], [], []
        taken = 0
        oldest = now - self._pending[0].t_submit
        while self._pending and taken < n_events:
            head = self._pending[0]
            room = n_events - taken
            if head.x.shape[0] <= room:
                self._pending.pop(0)
                part = head.x
            else:
                part = head.x[:room]
                head.x = head.x[room:]
            parts.append(part)
            segments.append((head.rid, taken, taken + part.shape[0]))
            deadlines.append(head.t_deadline)
            taken += part.shape[0]
        return BatchPlan(
            x=np.concatenate(parts, axis=0),
            bucket=self.bucket_for(taken),
            requests=tuple(segments),
            oldest_wait_s=oldest,
            reason=reason,
            deadlines=tuple(deadlines),
        )
