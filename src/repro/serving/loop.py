"""Live event-loop front-end: a request queue drained through the fabric.

LL-GNN's whole point is sustained *online* event selection — the L1
trigger drains a continuous stream of events under a hard latency
budget, it does not score pre-cut offline batches.  Until this module
the engine only ever saw offline streams (``run_stream``) or whole
batches (``infer``); this is the missing front-end: a single-threaded
event loop that takes individual requests as they arrive and pushes
them through

    :class:`~repro.serving.batcher.DeadlineBatcher`
        -> ``engine.run_plan(plan, sync=False)``
        -> per-request :class:`RequestFuture`

with the three properties a live front-end owes its operators:

* **bounded in-flight backpressure** — at most ``max_inflight`` plans
  are outstanding on the accelerator; a dispatch past that realizes the
  OLDEST plan first, so a burst cannot pin unbounded device buffers and
  completion latency is what applies the brake.
* **per-request completion futures** — a request may be split across
  several plans (it straddled a bucket cut) and those plans may realize
  out of order; each :class:`RequestFuture` reassembles its parts by
  dispatch sequence and completes exactly when every event it submitted
  has been served or shed.
* **queue-depth / shed accounting** — instantaneous backlog and
  in-flight occupancy land in :meth:`~repro.serving.metrics.
  ServingMetrics.gauge` (``queue_depth``, ``queue_requests``,
  ``inflight_plans``) next to the engine's monotonic shed/demotion
  counters, all in the same ``snapshot()``.

The loop is engine-agnostic: anything with ``bucket_sizes``,
``metrics`` and ``run_plan(plan, sync=False) -> handle`` serves — the
fault-tolerant :class:`~repro.serving.resilient.ResilientEngine` (whose
handles shed expired requests and recover down the degradation ladder)
in production, a bare :class:`~repro.serving.engine.ServingEngine` in
numerics tests.  It is deliberately single-threaded and clock-
injectable: every transition (flush, dispatch, backpressure, delivery)
happens inside ``submit()`` / ``poll()`` / ``drain()`` calls, so the
whole front-end is deterministic under test.
"""

from __future__ import annotations

import time

import numpy as np

from repro.serving.batcher import DeadlineBatcher


class RequestFuture:
    """Completion handle for one submitted request.

    Fills as the loop realizes the plans carrying this request's events;
    ``done`` flips once every event has been served or shed.  ``result()``
    returns the reassembled ``(n, ...)`` outputs — or ``None`` when any
    part was shed past its deadline (a partial answer is no answer for a
    trigger decision; the shed is already counted by the engine).
    """

    def __init__(self, rid: int, n_events: int):
        self.rid = rid
        self.n_events = int(n_events)
        self._parts: list[tuple[int, np.ndarray]] = []   # (dispatch seq, rows)
        self._served = 0
        self._shed = 0
        self._out = None

    @property
    def done(self) -> bool:
        return self._served + self._shed >= self.n_events

    @property
    def shed(self) -> bool:
        """True once any of this request's events were deadline-shed."""
        return self._shed > 0

    def result(self):
        """The request's outputs (``None`` if shed).  The loop must have
        completed it — call ``loop.drain()`` or pump ``loop.poll()`` until
        ``done``; a live front-end never blocks inside a future."""
        if not self.done:
            raise RuntimeError(
                f"request {self.rid} still has events in flight "
                f"({self._served + self._shed}/{self.n_events}); pump "
                "ServingLoop.poll() or call ServingLoop.drain() first")
        if self.shed:
            return None
        if self._out is None:
            # plans realize out of order; dispatch sequence restores the
            # submission order of this request's segments
            parts = [p for _, p in sorted(self._parts, key=lambda t: t[0])]
            self._out = parts[0] if len(parts) == 1 else np.concatenate(
                parts, axis=0)
            self._parts = []
        return self._out

    # -- loop-side delivery -------------------------------------------------

    def _deliver(self, seq: int, rows) -> None:
        self._parts.append((seq, rows))
        self._served += rows.shape[0]

    def _deliver_shed(self, n_events: int) -> None:
        self._shed += n_events


class ServingLoop:
    """Single-threaded event loop: submit -> batch -> dispatch -> deliver."""

    def __init__(self, engine, *, deadline_s: float = 2e-3,
                 max_inflight: int = 4, batcher: DeadlineBatcher | None = None,
                 clock=None):
        self.engine = engine
        # share the resilient engine's clock by default so request
        # deadlines and its shed decisions read the same time base
        self._clock = (clock if clock is not None
                       else getattr(engine, "_clock", time.monotonic))
        self.batcher = (batcher if batcher is not None
                        else DeadlineBatcher(engine.bucket_sizes,
                                             deadline_s=deadline_s,
                                             clock=self._clock))
        self.metrics = engine.metrics
        self.max_inflight = int(max_inflight)
        self._inflight: list[tuple[int, object, object]] = []  # (seq, h, plan)
        self._futures: dict[int, RequestFuture] = {}
        self._next_rid = 0
        self._next_seq = 0

    # -- introspection ------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        """Events accumulated in the batcher, not yet dispatched."""
        return self.batcher.pending_events

    @property
    def inflight(self) -> int:
        """Plans dispatched to the engine, not yet realized."""
        return len(self._inflight)

    @property
    def idle(self) -> bool:
        return self.queue_depth == 0 and not self._inflight

    # -- request flow -------------------------------------------------------

    def submit(self, x, *, deadline_s: float | None = None) -> RequestFuture:
        """Enqueue one request of ``x.shape[0]`` events; returns its
        future.  A full bucket flushes and dispatches immediately;
        otherwise the events wait for the batcher's deadline fuse
        (serviced by :meth:`poll`).  ``deadline_s`` is the request's
        serve-by budget — once expired, the engine sheds it instead of
        dispatching."""
        x = np.asarray(x)
        rid = self._next_rid
        self._next_rid += 1
        fut = RequestFuture(rid, x.shape[0])
        self._futures[rid] = fut
        self.metrics.incr("loop_requests")
        plans = self.batcher.submit(rid, x, deadline_s=deadline_s)
        # instantaneous backlog INCLUDING what this submission just cut —
        # the high-water mark capacity planning reads (gauge_max)
        self.metrics.gauge("queue_depth", self.batcher.pending_events
                           + sum(p.n_valid for p in plans))
        self._dispatch(plans)
        self._reap()
        self._update_gauges()
        return fut

    def poll(self) -> None:
        """One event-loop tick: fire the batcher's deadline fuse, dispatch
        what it flushed, deliver any plans that finished."""
        self._dispatch(self.batcher.poll())
        self._reap()
        self._update_gauges()

    def drain(self) -> None:
        """End of stream / shutdown: force-flush the batcher and realize
        every in-flight plan; afterwards every issued future is done."""
        self._dispatch(self.batcher.flush())
        while self._inflight:
            self._realize(self._inflight[0])
        self._update_gauges()

    # -- internals ----------------------------------------------------------

    def _dispatch(self, plans) -> None:
        for plan in plans:
            while len(self._inflight) >= self.max_inflight:
                # backpressure: the oldest plan's completion is the brake
                self._realize(self._inflight[0])
            handle = self.engine.run_plan(plan, sync=False)
            self._inflight.append((self._next_seq, handle, plan))
            self._next_seq += 1
            self.metrics.incr("loop_plans")

    def _reap(self) -> None:
        """Deliver every in-flight plan that is already realized-ready —
        non-blocking, so a fast small plan completes its futures even
        while an older big one still computes (out-of-order delivery)."""
        for entry in [e for e in self._inflight if e[1].ready]:
            self._realize(entry)

    def _realize(self, entry) -> None:
        seq, handle, plan = entry
        self._inflight.remove(entry)
        results = handle.result()
        rows = {}
        for rid, start, stop in plan.requests:
            rows[rid] = rows.get(rid, 0) + (stop - start)
        for rid, out in results.items():
            fut = self._futures.get(rid)
            if fut is None:
                continue
            if out is None:                       # engine shed this segment
                fut._deliver_shed(rows[rid])
            else:
                fut._deliver(seq, out)
            if fut.done:
                self.metrics.incr("loop_completed")
                # the caller holds the future; the loop can forget it
                del self._futures[rid]

    def _update_gauges(self) -> None:
        self.metrics.gauge("queue_depth", self.batcher.pending_events)
        self.metrics.gauge("queue_requests", self.batcher.pending_requests)
        self.metrics.gauge("inflight_plans", len(self._inflight))
