"""Serving subsystem: sharded engine + deadline batcher + metrics.

The production layer between request traffic and the fused JEDI-net
kernels — see engine.py for the architecture notes.
"""

from repro.serving.batcher import BatchPlan, DeadlineBatcher
from repro.serving.engine import PALLAS_PATHS, ServingEngine, serve_stream
from repro.serving.metrics import ServingMetrics, kgps, percentile

__all__ = [
    "BatchPlan",
    "DeadlineBatcher",
    "PALLAS_PATHS",
    "ServingEngine",
    "ServingMetrics",
    "kgps",
    "percentile",
    "serve_stream",
]
