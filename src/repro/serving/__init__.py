"""Serving subsystem: the unified fabric from request queue to kernels.

One stack, four layers (see core.py for the architecture notes):

* **core** — workload-agnostic :class:`ExecutionCore` + :class:`Workload`
  protocol (compile cache, pad-to-bucket, async in-flight window,
  watchdog, wall-union metrics, fault seams);
* **workloads** — :class:`ServingEngine` (sharded trigger paths) and
  :class:`LMEngine` (prefill + slot-recycling decode) instantiate the
  core;
* **resilience** — :class:`ResilientEngine` wraps a workload engine in
  the degradation ladder / shedding / health state machine; the
  :class:`Sentinel` (opt-in) adds the silent-corruption defense:
  golden canaries, terminal-rung shadow re-execution, canary-gated
  quarantine;
* **front-end** — :class:`ServingLoop` drains a live request queue
  through the :class:`DeadlineBatcher` into any of the above, with
  bounded-inflight backpressure and per-request :class:`RequestFuture`
  completion.
"""

from repro.serving.batcher import BatchPlan, DeadlineBatcher
from repro.serving.core import (
    ExecutionCore,
    PendingPlan,
    PendingResult,
    WatchdogTimeout,
    Workload,
    serve_stream,
)
from repro.serving.engine import ServingEngine, TriggerWorkload
from repro.serving.faults import (
    LOUD_SEAMS,
    SEAMS,
    SILENT_SEAMS,
    Fault,
    FaultInjector,
    InjectedFault,
    StaleCacheFn,
)
from repro.serving.lm import LMEngine, LMRequest, LMWorkload
from repro.serving.loop import RequestFuture, ServingLoop
from repro.serving.metrics import ServingMetrics, kgps, percentile
from repro.serving.resilient import (
    NonFiniteOutput,
    ResilientEngine,
    ResilientPending,
    ResilientPlan,
)
from repro.serving.sentinel import Sentinel, SentinelConfig
__all__ = [
    "LOUD_SEAMS",
    "SEAMS",
    "SILENT_SEAMS",
    "BatchPlan",
    "DeadlineBatcher",
    "ExecutionCore",
    "Fault",
    "FaultInjector",
    "InjectedFault",
    "LMEngine",
    "LMRequest",
    "LMWorkload",
    "NonFiniteOutput",
    "PendingPlan",
    "PendingResult",
    "RequestFuture",
    "ResilientEngine",
    "ResilientPending",
    "ResilientPlan",
    "Sentinel",
    "SentinelConfig",
    "ServingEngine",
    "ServingLoop",
    "ServingMetrics",
    "StaleCacheFn",
    "TriggerWorkload",
    "WatchdogTimeout",
    "Workload",
    "kgps",
    "percentile",
    "serve_stream",
]
