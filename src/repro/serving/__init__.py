"""Serving subsystem: sharded engine + deadline batcher + metrics.

The production layer between request traffic and the fused JEDI-net
kernels — see engine.py for the architecture notes.
"""

from repro.serving.batcher import BatchPlan, DeadlineBatcher
from repro.serving.engine import (
    PendingPlan,
    PendingResult,
    ServingEngine,
    WatchdogTimeout,
    serve_stream,
)
from repro.serving.faults import Fault, FaultInjector, InjectedFault
from repro.serving.metrics import ServingMetrics, kgps, percentile
from repro.serving.resilient import (
    NonFiniteOutput,
    ResilientEngine,
    ResilientPending,
)


def __getattr__(name):
    # PALLAS_PATHS is deprecated and computed from the registry on
    # access (see engine.__getattr__) — kept out of the eager imports
    # so `import repro.serving` doesn't force-load every path module.
    if name == "PALLAS_PATHS":
        from repro.serving import engine
        return engine.PALLAS_PATHS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "BatchPlan",
    "DeadlineBatcher",
    "Fault",
    "FaultInjector",
    "InjectedFault",
    "NonFiniteOutput",
    "PALLAS_PATHS",
    "PendingPlan",
    "PendingResult",
    "ResilientEngine",
    "ResilientPending",
    "ServingEngine",
    "ServingMetrics",
    "WatchdogTimeout",
    "kgps",
    "percentile",
    "serve_stream",
]
