"""Fault-tolerant serving: degradation ladder, shedding, health.

The L1 trigger never gets to stop: events arrive at a fixed cadence and
a pipeline that wedges drops physics on the floor.  Real-time trigger
systems (arXiv 2307.07289) therefore treat *continuous degraded
operation* as a requirement — a failing component is bypassed, not
debugged live.  :class:`ResilientEngine` is that layer for the serving
tier: it wraps one :class:`~repro.serving.engine.ServingEngine` per
rung of the forward path's **fallback chain**
(:func:`repro.core.paths.fallback_chain`, e.g. ``int8_fused_full ->
fused_full -> sr_split``) and guarantees the serve loop itself never
raises:

* **degradation ladder** — a rung that compile-fails, gets rejected by
  the VMEM-fit model, produces non-finite outputs, or wedges past the
  watchdog is demoted *per bucket*; the request is re-served on the
  next rung down, bottoming out in the chain's non-Pallas XLA
  reference (which is why the registry validates chains terminate
  there).
* **exponential-backoff re-promotion** — a demoted bucket periodically
  probes the ladder top again (first after ``probe_initial_s``,
  doubling to ``probe_max_s``); a healthy probe re-promotes, a failing
  one re-arms the backoff.  Probes ride live requests, so re-promotion
  costs one request the probe's failure latency, never a stall.
* **deadline enforcement + shedding** — requests carry absolute
  deadlines (from :class:`~repro.serving.batcher.DeadlineBatcher` plans
  or ``infer(deadline=...)``); an expired request is shed *before*
  dispatch (counted, never served) — accelerator time is not spent on
  answers nobody is waiting for.
* **bounded in-flight queue** — at most ``max_inflight`` async
  dispatches outstanding; a full queue applies backpressure by
  realizing the oldest first.
* **watchdog** — realization polls readiness with a ``watchdog_s``
  budget instead of blocking forever on a stuck dispatch; a timeout
  demotes the rung and re-serves on the fallback.
* **health state machine** — ``healthy / degraded / shedding /
  quarantined / down`` with per-bucket detail (:meth:`health`), driven
  by the shared :class:`~repro.serving.metrics.ServingMetrics`
  counters; surfaced by ``trigger_serve --health``.
* **silent-corruption sentinel** (opt-in via ``sentinel=``) — the loud
  ladder above never sees *finite wrong answers*; a
  :class:`~repro.serving.sentinel.Sentinel` adds golden canaries,
  duty-cycled shadow re-execution on the terminal rung, and
  canary-gated quarantine (``promote_after`` consecutive clean
  canaries to re-promote, instead of one live probe).  See
  :mod:`repro.serving.sentinel`.

Every transition is deterministic and injectable
(:mod:`repro.serving.faults`), so the whole ladder is unit-testable on
CPU — see ``tests/test_faults.py`` (pytest marker ``chaos``).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import paths as forward_paths
from repro.serving.engine import ServingEngine, WatchdogTimeout
from repro.serving.faults import InjectedFault
from repro.serving.metrics import ServingMetrics
from repro.serving.sentinel import Sentinel, SentinelConfig

#: Health states, worst wins: any bucket with its whole ladder failing
#: is ``down``; a sentinel quarantine (silent corruption caught, rung
#: awaiting canary requalification) beats recent shedding, which beats
#: mere degradation.
HEALTH_STATES = ("healthy", "degraded", "shedding", "quarantined", "down")


class NonFiniteOutput(RuntimeError):
    """A rung returned NaN/Inf logits — numerics failure, demote."""


class _BucketState:
    """Ladder position + probe schedule for one compile bucket."""

    __slots__ = ("level", "backoff_s", "next_probe", "demotions", "down",
                 "quarantined", "q_level", "clean")

    def __init__(self, level: int, backoff_s: float):
        self.level = level           # active chain index (0 = primary)
        self.backoff_s = backoff_s   # current probe backoff
        self.next_probe: float | None = None   # absolute clock time
        self.demotions = 0
        self.down = False            # last serve exhausted the ladder
        self.quarantined = False     # sentinel caught silent corruption
        self.q_level: int | None = None   # the quarantined rung
        self.clean = 0               # consecutive clean canaries at q_level


class ResilientPending:
    """Async handle with realization-time recovery: a fault surfacing at
    ``result()`` (watchdog timeout, NaN logits) is counted, demotes the
    rung, and the request is re-served synchronously down the ladder —
    the caller sees logits either way, never an exception."""

    def __init__(self, engine: "ResilientEngine", x, bucket: int,
                 level: int, pending, record: bool):
        self._engine = engine
        self._x = x
        self._bucket = bucket
        self._level = level
        self._pending = pending
        self._record = record
        self._out = None
        self._done = False

    @property
    def ready(self) -> bool:
        return self._done or self._pending.ready

    def result(self) -> np.ndarray:
        if not self._done:
            self._out = self._engine._realize(
                self, self._pending, self._x, self._bucket, self._level,
                record=self._record)
            self._done = True
            self._pending = None     # free device buffers
        return self._out


class ResilientPlan:
    """A dispatched :class:`~repro.serving.batcher.BatchPlan` with
    deadline shedding already applied at dispatch: ``result()``
    reassembles ``{rid: logits | None}`` (``None`` marks a shed
    request), recovering down-ladder like any other realization.  The
    event loop (:mod:`repro.serving.loop`) holds these as its bounded
    in-flight window."""

    def __init__(self, results: dict, keep, pending):
        self._results = results          # pre-seeded with shed rids -> None
        self._keep = keep                # ((rid, start, stop), ...) served
        self._pending = pending          # ResilientPending | None

    @property
    def ready(self) -> bool:
        return self._pending is None or self._pending.ready

    def result(self) -> dict:
        if self._pending is not None:
            logits = self._pending.result()      # never raises
            parts: dict[int, list] = {}
            pos = 0
            for rid, start, stop in self._keep:
                n = stop - start
                parts.setdefault(rid, []).append(logits[pos:pos + n])
                pos += n
            for rid, ps in parts.items():
                self._results[rid] = np.concatenate(ps, axis=0)
            self._pending = None
            self._keep = ()
        return self._results


class ResilientEngine:
    """Never-raise serving over a forward path's degradation ladder."""

    def __init__(self, params, cfg, *, forward: str = "fused_full",
                 interpret: bool | None = None, mesh="auto",
                 bucket_sizes=None, max_batch: int = 1024,
                 metrics: ServingMetrics | None = None, injector=None,
                 watchdog_s: float | None = 30.0, max_inflight: int = 8,
                 probe_initial_s: float = 0.25, probe_max_s: float = 60.0,
                 shed_window_s: float = 5.0, clock=time.monotonic,
                 sentinel: SentinelConfig | bool | None = None):
        self.chain = forward_paths.fallback_chain(forward)
        self.cfg = cfg
        self.forward = forward
        self._engines = {}
        self.metrics = metrics if metrics is not None else ServingMetrics()
        self.injector = injector
        self.watchdog_s = watchdog_s
        self.max_inflight = int(max_inflight)
        self.probe_initial_s = float(probe_initial_s)
        self.probe_max_s = float(probe_max_s)
        self.shed_window_s = float(shed_window_s)
        self._clock = clock
        self._params = params        # RAW params: each rung's spec applies
        self._interpret = interpret  # its own transform at construction
        self._mesh = mesh
        self._max_batch = int(max_batch)
        self._construct_failed: set[int] = set()
        self._inflight: list[ResilientPending] = []
        self._last_shed: float | None = None
        self._last_down: float | None = None

        # The base rung is the first CONSTRUCTIBLE chain level — normally
        # the primary; a path whose engine cannot even be built for this
        # cfg (unsupported compute dtype, ...) is skipped permanently.
        # Its ladder becomes the canonical bucket set every other rung
        # is built with, so per-bucket state means the same batch shape
        # on every rung.
        base, err = None, None
        for lvl in range(len(self.chain)):
            try:
                eng = ServingEngine(
                    params, cfg, forward=self.chain[lvl],
                    interpret=interpret, mesh=mesh,
                    bucket_sizes=bucket_sizes, max_batch=max_batch,
                    metrics=self.metrics, injector=injector)
            except Exception as e:    # noqa: BLE001 — rung skip, counted
                self._construct_failed.add(lvl)
                self.metrics.incr("construct_failures")
                err = e
                continue
            base, self._engines[lvl] = lvl, eng
            break
        if base is None:
            raise RuntimeError(
                f"no rung of fallback chain {self.chain} is constructible "
                f"for this config; last error: {err!r}") from err
        self._base_level = base
        self.bucket_sizes = self._engines[base].bucket_sizes
        self._state: dict[int, _BucketState] = {}
        if sentinel is True:
            sentinel = SentinelConfig()
        self.sentinel = (Sentinel(self, sentinel, clock=clock)
                         if sentinel else None)

    # -- introspection -------------------------------------------------------

    @property
    def metrics(self) -> ServingMetrics:
        return self._metrics

    @metrics.setter
    def metrics(self, m: ServingMetrics) -> None:
        # every rung records into ONE shared metrics object; swapping it
        # (benchmarks reset the window per bucket) must re-point them all
        self._metrics = m
        for eng in self._engines.values():
            eng.metrics = m

    @property
    def n_shards(self) -> int:
        return self._engines[self._base_level].n_shards

    @property
    def interpret(self) -> bool:
        return self._engines[self._base_level].interpret

    def bucket_for(self, n_events: int) -> int:
        return self._engines[self._base_level].bucket_for(n_events)

    def active_path(self, bucket: int) -> str:
        """The chain rung currently serving ``bucket``."""
        return self.chain[self._bucket_state(bucket).level]

    def roofline(self, buckets=None, *, compute_bytes: int = 2) -> dict:
        """Roofline of the BASE rung (the intended serving path) — the
        number degraded operation is measured against."""
        return self._engines[self._base_level].roofline(
            buckets, compute_bytes=compute_bytes)

    def health(self) -> dict:
        """The health state machine's current view.

        ``state`` is the worst of: ``down`` (some bucket's whole ladder
        failed on its last serve), ``quarantined`` (the sentinel caught
        silent corruption on some bucket's rung; it re-promotes only
        after ``promote_after`` clean canaries), ``shedding`` (deadline
        sheds within the last ``shed_window_s``), ``degraded`` (some
        bucket serving off a fallback rung), ``healthy``.  ``buckets``
        carries the per-bucket detail the fleet's load balancer would
        key on.
        """
        now = self._clock()
        buckets = {}
        for b in sorted(self._state):
            st = self._state[b]
            buckets[b] = {
                "path": self.chain[st.level],
                "level": st.level,
                "demotions": st.demotions,
                "down": st.down,
                "quarantined": st.quarantined,
                "quarantined_path": (None if st.q_level is None
                                     else self.chain[st.q_level]),
                "clean_canaries": st.clean,
                "next_probe_in_s": (
                    None if st.next_probe is None
                    else max(0.0, st.next_probe - now)),
            }
        recent = (self._last_shed is not None
                  and now - self._last_shed < self.shed_window_s)
        if any(st.down for st in self._state.values()):
            state = "down"
        elif any(st.quarantined for st in self._state.values()):
            state = "quarantined"
        elif recent:
            state = "shedding"
        elif any(st.level > self._base_level
                 for st in self._state.values()):
            state = "degraded"
        else:
            state = "healthy"
        report = {"state": state, "chain": list(self.chain),
                  "base_path": self.chain[self._base_level],
                  "buckets": buckets, "inflight": len(self._inflight),
                  "counters": self.metrics.counters,
                  "gauges": self.metrics.gauges}
        if self.sentinel is not None:
            report["sentinel"] = self.sentinel.detail()
        return report

    # -- rung management -----------------------------------------------------

    def _engine_for(self, level: int) -> ServingEngine:
        if level in self._construct_failed:
            raise RuntimeError(
                f"rung {self.chain[level]!r} permanently skipped "
                "(construction failed)")
        eng = self._engines.get(level)
        if eng is None:
            try:
                eng = ServingEngine(
                    self._params, self.cfg, forward=self.chain[level],
                    interpret=self._interpret, mesh=self._mesh,
                    bucket_sizes=self.bucket_sizes,
                    max_batch=self._max_batch, metrics=self.metrics,
                    injector=self.injector)
            except Exception:
                self._construct_failed.add(level)
                self.metrics.incr("construct_failures")
                raise
            self._engines[level] = eng
        return eng

    def _bucket_state(self, bucket: int) -> _BucketState:
        st = self._state.get(bucket)
        if st is None:
            st = self._state[bucket] = _BucketState(
                self._base_level, self.probe_initial_s)
        return st

    def _start_level(self, st: _BucketState, now: float) -> tuple[int, bool]:
        """Where this serve enters the ladder: the active rung, or the
        ladder top when the bucket's re-promotion probe is due.
        Quarantined buckets never probe on live traffic — a rung that
        served silent corruption can LOOK healthy to a probe, so
        requalification is gated on clean canaries instead."""
        if st.quarantined:
            return st.level, False
        if (st.level > self._base_level and st.next_probe is not None
                and now >= st.next_probe):
            self.metrics.incr("probes")
            return self._base_level, True
        return st.level, False

    def _quarantine(self, bucket: int, level: int) -> None:
        """Sentinel trip on ``level``: evict the poisoned compile-cache
        entry (build-time corruption lives in the cached callable),
        demote the bucket below the rung, and gate re-promotion on
        clean canaries rather than live probes."""
        st = self._bucket_state(bucket)
        eng = self._engines.get(level)
        if eng is not None:
            eng.evict(bucket)
        self.metrics.incr("sentinel_trips")
        if not (st.quarantined and st.q_level == level):
            st.quarantined = True
            st.q_level = level
            self.metrics.incr("quarantines")
        st.clean = 0
        demote_to = min(level + 1, len(self.chain) - 1)
        if demote_to > st.level:
            st.level = demote_to
            st.demotions += 1
            self.metrics.incr("demotions")
        st.next_probe = None     # canary-gated, not probe-gated

    def _requalify(self, bucket: int) -> None:
        """``promote_after`` consecutive clean canaries at the
        quarantined rung: lift the quarantine and re-promote to it."""
        st = self._bucket_state(bucket)
        lvl = st.q_level
        st.quarantined = False
        st.q_level = None
        st.clean = 0
        if lvl is not None and lvl < st.level:
            st.level = lvl
            self.metrics.incr("promotions")
        st.backoff_s = self.probe_initial_s
        st.next_probe = None
        self.metrics.incr("requalifications")

    def _count_failure(self, exc: Exception) -> None:
        if isinstance(exc, InjectedFault) and exc.seam == "compile":
            self.metrics.incr("compile_failures")
        elif isinstance(exc, WatchdogTimeout):
            self.metrics.incr("watchdog_timeouts")
        elif isinstance(exc, NonFiniteOutput):
            self.metrics.incr("nonfinite_batches")
        else:
            # real lowering errors surface through infer() untyped; they
            # land here together with runtime dispatch failures
            self.metrics.incr("dispatch_failures")

    def _rung_failed(self, st: _BucketState, level: int, now: float,
                     exc: Exception) -> None:
        """Bookkeeping for one failed serve attempt at ``level``: demote
        below it (if not already), schedule the next probe with
        exponential backoff."""
        self._count_failure(exc)
        # clamp: a terminal-rung failure marks the bucket down (caller),
        # it must not index the level past the chain
        demote_to = min(level + 1, len(self.chain) - 1)
        if demote_to > st.level:
            st.level = demote_to
            st.demotions += 1
            self.metrics.incr("demotions")
        st.next_probe = now + st.backoff_s
        st.backoff_s = min(st.backoff_s * 2, self.probe_max_s)

    def _rung_served(self, st: _BucketState, level: int) -> None:
        st.down = False
        if level < st.level:         # successful probe: re-promote
            st.level = level
            st.backoff_s = self.probe_initial_s
            st.next_probe = None
            self.metrics.incr("promotions")
        if level > self._base_level:
            self.metrics.incr("fallback_batches")

    def _serve_once(self, level: int, x, *, record: bool) -> np.ndarray:
        out = self._engine_for(level).infer(
            x, record=record, timeout_s=self.watchdog_s)
        if not np.isfinite(out).all():
            raise NonFiniteOutput(
                f"rung {self.chain[level]!r} returned non-finite logits")
        return out

    def _last_resort(self, n: int) -> np.ndarray:
        """Every rung failed: the loop still must not raise.  Return
        NaN logits (the caller's schema holds; downstream consumers see
        an unambiguous 'no answer') and mark the engine down."""
        self.metrics.incr("failed_requests")
        self._last_down = self._clock()
        n_targets = getattr(self.cfg, "n_targets", 1)
        return np.full((n, n_targets), np.nan, np.float32)

    def _serve_ladder(self, x, *, record: bool = True,
                      start: int | None = None) -> np.ndarray:
        """Serve ``x`` trying rungs from ``start`` (default: the probe/
        active decision) downward.  Never raises."""
        x = np.asarray(x)
        bucket = self.bucket_for(min(x.shape[0], self.bucket_sizes[-1]))
        st = self._bucket_state(bucket)
        now = self._clock()
        lvl = self._start_level(st, now)[0] if start is None else start
        while lvl < len(self.chain):
            if lvl in self._construct_failed:
                lvl += 1
                continue
            try:
                out = self._serve_once(lvl, x, record=record)
            except Exception as e:   # noqa: BLE001 — ladder catches all
                self._rung_failed(st, lvl, self._clock(), e)
                lvl += 1
                continue
            self._rung_served(st, lvl)
            if record and self.sentinel is not None:
                # canaries ride the RUNG engines directly, so the
                # sentinel never re-enters this ladder
                self.sentinel.observe(x, out, bucket, lvl)
            return out
        st.down = True
        return self._last_resort(x.shape[0])

    # -- serving API ---------------------------------------------------------

    def _shed(self, n_events: int) -> None:
        self.metrics.incr("shed_requests")
        self.metrics.incr("shed_events", n_events)
        self._last_shed = self._clock()

    def _gauge_inflight(self) -> None:
        self.metrics.gauge("inflight", len(self._inflight))

    def warm(self, buckets=None) -> None:
        """Pre-serve zeros through every bucket — compile cost (and any
        compile-time demotion) paid before traffic arrives."""
        c = self.cfg
        for b in buckets if buckets is not None else self.bucket_sizes:
            self._serve_ladder(
                np.zeros((b, c.n_objects, c.n_features), np.float32),
                record=False)

    def infer(self, x, *, deadline: float | None = None, record: bool = True,
              sync: bool = True):
        """Serve ``x`` through the ladder; never raises.

        ``deadline`` is an absolute time on this engine's clock; a
        request already past it is SHED — counted, never dispatched —
        and ``None`` is returned (async: no handle is created).
        ``sync=False`` returns a :class:`ResilientPending`; at most
        ``max_inflight`` are outstanding — a full queue blocks on the
        oldest (backpressure) before dispatching the new one.
        """
        x = np.asarray(x)
        if deadline is not None and self._clock() >= deadline:
            self._shed(x.shape[0])
            return None
        if sync:
            return self._serve_ladder(x, record=record)

        # async: drain the queue head until a slot frees up
        while len(self._inflight) >= self.max_inflight:
            self._inflight[0].result()   # realization removes it
            if deadline is not None and self._clock() >= deadline:
                self._shed(x.shape[0])   # expired while backpressured
                return None
        bucket = self.bucket_for(min(x.shape[0], self.bucket_sizes[-1]))
        st = self._bucket_state(bucket)
        lvl = self._start_level(st, self._clock())[0]
        pending = None
        while lvl < len(self.chain):
            if lvl in self._construct_failed:
                lvl += 1
                continue
            try:
                # dispatch-time faults (compile, dispatch exception)
                # surface here synchronously; realization-time faults
                # (stuck, NaN) surface in ResilientPending.result()
                pending = self._engine_for(lvl).infer(
                    x, record=record, sync=False)
                break
            except Exception as e:   # noqa: BLE001 — ladder catches all
                self._rung_failed(st, lvl, self._clock(), e)
                lvl += 1
        if pending is None:
            st.down = True
            rp = ResilientPending(self, x, bucket, len(self.chain), None,
                                  record)
            rp._out, rp._done = self._last_resort(x.shape[0]), True
            return rp
        rp = ResilientPending(self, x, bucket, lvl, pending, record)
        self._inflight.append(rp)
        self._gauge_inflight()
        return rp

    def _realize(self, rp: ResilientPending, pending, x, bucket: int,
                 level: int, *, record: bool) -> np.ndarray:
        """Realize an async dispatch; recover down-ladder on failure."""
        st = self._bucket_state(bucket)
        try:
            out = pending.result(timeout_s=self.watchdog_s)
            if not np.isfinite(out).all():
                raise NonFiniteOutput(
                    f"rung {self.chain[level]!r} returned non-finite "
                    "logits")
        except Exception as e:       # noqa: BLE001 — ladder catches all
            self._rung_failed(st, level, self._clock(), e)
            out = self._serve_ladder(x, record=record, start=level + 1)
        else:
            self._rung_served(st, level)
            if record and self.sentinel is not None:
                self.sentinel.observe(x, out, bucket, level)
        if rp in self._inflight:
            self._inflight.remove(rp)
            self._gauge_inflight()
        return out

    def run_plan(self, plan, *, sync: bool = True):
        """Execute a :class:`~repro.serving.batcher.BatchPlan`, shedding
        segments whose deadline has already expired (they are never
        dispatched); returns ``{rid: logits | None}`` — ``None`` marks a
        shed request.

        ``sync=False`` returns a :class:`ResilientPlan` right after the
        async dispatch — the event loop's unit of in-flight work: the
        next plan's pad + dispatch overlaps this one's compute, and
        realization-time faults still recover down the ladder."""
        now = self._clock()
        keep, results = [], {}
        for i, (rid, start, stop) in enumerate(plan.requests):
            t_deadline = plan.deadline_for(i)
            if t_deadline is not None and now >= t_deadline:
                self._shed(stop - start)
                results[rid] = None
            else:
                keep.append((rid, start, stop))
        if not keep:
            return results if sync else ResilientPlan(results, (), None)
        x = np.concatenate([plan.x[s:e] for _, s, e in keep], axis=0)
        rp = ResilientPlan(results, tuple(keep), self.infer(x, sync=False))
        return rp.result() if sync else rp

    def run_stream(self, stream, *, warmup: int = 2) -> dict:
        """The double-buffered fixed-size stream loop, ladder-protected:
        a rung that fails to compile (or raises mid-stream) demotes and
        the WHOLE stream re-runs on the fallback — the hot path itself
        stays the sub-engine's zero-overhead feed loop."""
        stream = list(stream)
        if not stream:
            return self._engines[self._base_level].run_stream(stream,
                                                              warmup=warmup)
        bucket = self.bucket_for(stream[0].shape[0])
        st = self._bucket_state(bucket)
        lvl = self._start_level(st, self._clock())[0]
        last_err: Exception | None = None
        while lvl < len(self.chain):
            if lvl in self._construct_failed:
                lvl += 1
                continue
            try:
                res = self._engine_for(lvl).run_stream(stream, warmup=warmup)
            except Exception as e:   # noqa: BLE001 — ladder catches all
                self._rung_failed(st, lvl, self._clock(), e)
                last_err = e
                lvl += 1
                continue
            self._rung_served(st, lvl)
            if self.sentinel is not None:
                # post-hoc: the hot stream loop itself stays untouched
                self.sentinel.verify_stream(stream, bucket, lvl)
            return res
        st.down = True
        self.metrics.incr("failed_requests")
        self._last_down = self._clock()
        raise RuntimeError(
            f"every rung of {self.chain} failed for the stream "
            f"(bucket {bucket}); last error: {last_err!r}") from last_err
