"""Workload-agnostic execution core: the serving fabric's bottom layer.

The bucketed trigger engine (:mod:`repro.serving.engine`) and the LM
slot-recycling driver used to be two unrelated serving stacks — same
compile caching, same padding discipline, same metrics questions,
zero shared code.  This module is the split that unifies them: the
machinery that is identical for EVERY workload lives in
:class:`ExecutionCore`, and everything workload-specific — how to
build a compiled callable for a bucket, how to pad a request, what the
bucket ladder is — is declared by a :class:`Workload`.

``ExecutionCore`` owns, for any workload:

* **warm compile cache** — callables cached per workload cache key
  (built on miss, fault-injectable at the ``compile`` seam);
* **pad-to-bucket dispatch** — requests padded up the workload's
  ladder so arbitrary request counts reuse a handful of compilations;
* **async in-flight window** — :meth:`infer` with ``sync=False``
  returns a :class:`PendingResult`; oversized requests pipeline chunks
  with at most :data:`MAX_INFLIGHT_CHUNKS` outstanding;
* **watchdog** — realization with a ``timeout_s`` budget raises
  :class:`WatchdogTimeout` instead of blocking forever on a wedged
  dispatch;
* **wall-union metrics** — KGPS wall time is the UNION of dispatch
  windows (overlap-safe in any realization order), recorded into a
  shared :class:`~repro.serving.metrics.ServingMetrics`;
* **fault seams** — an optional
  :class:`~repro.serving.faults.FaultInjector` is consulted at the
  compile / dispatch / input / output boundaries.

:class:`~repro.serving.engine.ServingEngine` is the trigger
instantiation (a :class:`Workload` wrapping a
:class:`~repro.core.paths.PathSpec` + data-parallel mesh);
:class:`~repro.serving.lm.LMEngine` is the LM-decode instantiation.
Both are driven by the same live front-end
(:class:`~repro.serving.loop.ServingLoop`).
"""

from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.metrics import ServingMetrics, kgps

# In-flight dispatch depth for chunked infer(): enough to hide pad/H2D
# behind compute, small enough that a huge request can't pin unbounded
# device buffers.
MAX_INFLIGHT_CHUNKS = 4

# Retained merged busy-window intervals for overlap-safe KGPS wall
# accounting — far more than any realistic number of concurrently
# outstanding PendingResults, small enough that a long-running engine
# stays O(1) per dispatch.
_MAX_WALL_WINDOWS = 64


class WatchdogTimeout(RuntimeError):
    """A dispatched result failed to become ready within the watchdog
    budget (``PendingResult.result(timeout_s=...)``).  The serve loop
    must never block forever on a wedged dispatch — the resilience
    layer catches this, counts it, and re-serves via the fallback
    chain."""


class Workload:
    """What a workload must declare for :class:`ExecutionCore` to serve it.

    A workload is the *what* of serving — the compiled computation, its
    input shape discipline and its bucket policy; the core is the *how*
    — caching, padding, dispatch, accounting, fault tolerance.  The
    trigger workload wraps a forward-path :class:`~repro.core.paths.
    PathSpec` over a device mesh; the LM workload wraps prefill +
    decode-step over a slot-batched KV cache.  Subclasses override the
    hooks below; the defaults cover the common dense-batch case.

    ``name`` labels compile-cache keys, fault-injection seams and
    metrics, so one injector can target exactly one workload/path.
    """

    name: str = "workload"

    # -- bucket policy ------------------------------------------------------

    def bucket_ladder(self, max_batch: int) -> list[int]:
        """The pad-to-bucket ladder this workload earns for ``max_batch``."""
        raise NotImplementedError

    def validate_buckets(self, bucket_sizes: list[int]) -> None:
        """Veto a ladder the workload cannot serve (e.g. a bucket that
        does not divide the data mesh).  Default: anything goes."""

    # -- compilation --------------------------------------------------------

    def cache_key(self, bucket) -> tuple:
        """Everything a compiled callable's identity depends on."""
        return (self.name, bucket)

    def build(self, bucket):
        """A jitted async-dispatch callable for one bucket shape."""
        raise NotImplementedError

    # -- request shaping ----------------------------------------------------

    def pad(self, x: np.ndarray, bucket: int) -> np.ndarray:
        """Pad a request's leading axis up to ``bucket`` rows."""
        n = x.shape[0]
        if n == bucket:
            return x
        return np.concatenate(
            [x, np.zeros((bucket - n, *x.shape[1:]), x.dtype)], axis=0)

    def placeholder(self, bucket: int) -> np.ndarray:
        """A zero input of the bucket's shape (for :meth:`ExecutionCore.
        warm`)."""
        raise NotImplementedError

    # -- silent fault seams (optional) --------------------------------------

    def corrupted(self, seam: str, factor: float, bucket):
        """A compiled callable built from silently corrupted params, for
        the ``scale_drift`` / ``weight_corrupt`` fault seams — or
        ``None`` when the corruption does not apply to this workload
        (no params, nothing to drift).  Default: not corruptible; the
        fault then does not fire (see ``FaultInjector.corrupt_build``).
        """
        return None


def serve_stream(fwd, stream, *, warmup: int = 2, metrics=None, bucket=None):
    """Double-buffered device-feed loop; returns per-batch latencies.

    ``fwd`` must be an async-dispatch callable (jitted) taking a host or
    device array; latencies are seconds from host handoff to
    logits-ready.  Batch k+1's ``device_put`` is issued while batch k is
    still computing, so H2D transfer hides behind compute.  The first
    ``warmup`` batches (compile + cache warm) are excluded from stats;
    a stream no longer than ``warmup`` yields empty stats, not a crash.

    When ``metrics`` is given every post-warmup batch is recorded there
    (``bucket`` labels the records; defaults to the batch row count).
    """
    latencies = []
    events = 0
    it = iter(stream)

    # prime the pipeline: first transfer issued before the loop body
    try:
        nxt = jax.device_put(next(it))
    except StopIteration:
        return latencies, events, 0.0

    # wall time starts at the last warmup batch; with no warmup it starts
    # here, so KGPS is well-defined for any stream length
    t_start = time.perf_counter() if warmup == 0 else None
    k = 0
    while nxt is not None:
        cur = nxt
        t0 = time.perf_counter()
        out = fwd(cur)                      # async dispatch
        try:
            nxt = jax.device_put(next(it))  # overlap next H2D with compute
        except StopIteration:
            nxt = None
        jax.block_until_ready(out)
        t1 = time.perf_counter()
        k += 1
        if k <= warmup:                     # exclude compile from stats
            t_start = time.perf_counter()
            continue
        latencies.append(t1 - t0)
        events += cur.shape[0]
        if metrics is not None:
            metrics.record_batch(t1 - t0, cur.shape[0],
                                 bucket or cur.shape[0])
    wall = (time.perf_counter() - t_start) if t_start else 0.0
    return latencies, events, wall


class PendingResult:
    """In-flight inference: dispatched to the device, not yet waited on.

    Holds the un-blocked device buffers of one :meth:`ExecutionCore.infer`
    call.  ``result()`` blocks (once), records metrics per chunk, and
    returns the host logits.  Recorded latency is dispatch-to-REALIZATION
    (an upper bound on dispatch-to-ready: the host has no device-side
    completion timestamp) — realize promptly, or the caller's idle time
    lands in the percentiles.  Wall time for KGPS is overlap-safe in any
    realization order (see ``ExecutionCore._record_wall_window``).
    """

    def __init__(self, engine, chunks, *, record: bool = True):
        self._engine = engine
        self._chunks = chunks            # [(device_out, n_valid, bucket, t0)]
        self._record = record
        self._out = None

    @property
    def ready(self) -> bool:
        """True when every dispatched buffer is done (non-blocking where
        the jax version exposes readiness; conservatively False else)."""
        try:
            return all(c[0].is_ready() for c in self._chunks)
        except AttributeError:
            return False

    @staticmethod
    def _wait_ready(out, deadline: float | None) -> None:
        """Block until ``out`` is ready; with a ``deadline`` (absolute
        ``perf_counter`` time), raise :class:`WatchdogTimeout` past it —
        a wedged dispatch must park the watchdog, not the whole serve
        loop.  The timed wait blocks in a daemon thread (the efficient
        runtime wait, zero poll-quantization overhead on the fast path);
        on timeout the thread is abandoned with the wedged buffer.
        Results without a readiness probe (plain host arrays) block
        directly."""
        if deadline is None or getattr(out, "is_ready", None) is None:
            jax.block_until_ready(out)
            return
        done = threading.Event()
        threading.Thread(
            target=lambda: (jax.block_until_ready(out), done.set()),
            daemon=True).start()
        if not done.wait(max(0.0, deadline - time.perf_counter())):
            raise WatchdogTimeout(
                "dispatched result not ready within the watchdog "
                "budget; abandoning the in-flight buffer")

    def result(self, *, timeout_s: float | None = None) -> np.ndarray:
        if self._out is None:
            deadline = (None if timeout_s is None
                        else time.perf_counter() + timeout_s)
            outs = []
            t_first, t_last, events = None, None, 0
            for out, n_valid, bucket, t0 in self._chunks:
                self._wait_ready(out, deadline)
                t1 = time.perf_counter()
                if self._record:
                    self._engine.metrics.record_batch(t1 - t0, n_valid, bucket)
                t_first = t0 if t_first is None else t_first
                t_last, events = t1, events + n_valid
                outs.append(np.asarray(out)[:n_valid])
            if self._record and t_first is not None:
                # ONE wall window for the whole dispatch, merged into the
                # engine's busy-time union: overlapped chunks AND
                # overlapped concurrent dispatches — realized in ANY
                # order — must not double-count elapsed time (KGPS is
                # events/wall, not events/sum-of-latencies)
                self._engine._record_wall_window(t_first, t_last, events)
            self._out = np.concatenate(outs, axis=0)
            self._chunks = ()            # free device buffers
        return self._out


class PendingPlan:
    """A dispatched :class:`~repro.serving.batcher.BatchPlan` awaiting
    realization: ``result()`` blocks and reassembles per-request logits."""

    def __init__(self, pending: PendingResult, requests):
        self._pending = pending
        self._requests = requests

    @property
    def ready(self) -> bool:
        return self._pending.ready

    def result(self, *, timeout_s: float | None = None) -> dict:
        logits = self._pending.result(timeout_s=timeout_s)
        out: dict[int, list] = {}
        for rid, start, stop in self._requests:
            out.setdefault(rid, []).append(logits[start:stop])
        return {rid: np.concatenate(parts, axis=0)
                for rid, parts in out.items()}


class ExecutionCore:
    """Bucketed, metered, fault-injectable execution over one workload."""

    def __init__(self, workload: Workload, *, bucket_sizes=None,
                 max_batch: int = 1024,
                 metrics: ServingMetrics | None = None, injector=None):
        self.workload = workload
        self.metrics = metrics if metrics is not None else ServingMetrics()
        # Fault-injection seams (serving/faults.py): None in production.
        # The injector is consulted at compile, dispatch, input and
        # output boundaries — see the seam calls below.
        self.injector = injector
        if bucket_sizes is None:
            bucket_sizes = workload.bucket_ladder(max_batch)
        self.bucket_sizes = sorted(int(b) for b in bucket_sizes)
        workload.validate_buckets(self.bucket_sizes)
        # merged busy-time intervals (perf_counter): KGPS wall is the
        # UNION of dispatch windows, never a double-counted sum
        self._wall_windows: list[tuple[float, float]] = []
        self._cache: dict[tuple, object] = {}

    # -- compile-cache management ------------------------------------------

    def compiled_for(self, bucket):
        """The cached jitted callable for one bucket shape (built on miss).

        ``bucket`` is passed through to the workload verbatim, so it can
        be a row-count rung (trigger) or any hashable shape descriptor
        (the LM workload keys ``("prefill", L)`` / ``("decode", slots)``
        through the same cache).
        """
        key = self.workload.cache_key(bucket)
        fn = self._cache.get(key)
        if fn is None:
            if self.injector is not None:
                # compile seam: fires only on a cache MISS — a warm
                # callable never recompiles, so it cannot re-fail here
                self.injector.check("compile", path=self.workload.name,
                                    bucket=bucket)
                # silent build seams (scale_drift / weight_corrupt):
                # the cached callable is built from corrupted params —
                # finite wrong answers persist until the entry is
                # rebuilt (evict()), exactly like a poisoned cache
                fn = self.injector.corrupt_build(self.workload, bucket)
            if fn is None:
                fn = self.workload.build(bucket)
            if self.injector is not None:
                # stale_cache seam: the entry replays the previous
                # dispatch's output — real logits, wrong events
                fn = self.injector.wrap_stale(
                    fn, path=self.workload.name, bucket=bucket)
            self._cache[key] = fn
        return fn

    def evict(self, bucket) -> None:
        """Drop one bucket's cached callable so the next dispatch
        rebuilds it.  The sentinel's quarantine calls this on a silent-
        corruption trip: a poisoned compiled entry must be rebuilt from
        source params, never re-trusted."""
        self._cache.pop(self.workload.cache_key(bucket), None)

    @property
    def cache_size(self) -> int:
        return len(self._cache)

    def _record_wall_window(self, t0: float, t1: float, events: int) -> None:
        """Record ``events`` over the part of [t0, t1] not already counted.

        Maintains the union of busy windows, so overlapping dispatches
        realized in any order contribute exactly their NEW coverage to
        the KGPS wall — never a double-counted sum, never dropped time.
        The merged list stays tiny: contiguous serving collapses to one
        interval.
        """
        segs = [(t0, t1)]
        for s, e in self._wall_windows:        # subtract existing coverage
            nxt = []
            for a, b in segs:
                if e <= a or s >= b:
                    nxt.append((a, b))
                    continue
                if a < s:
                    nxt.append((a, s))
                if e < b:
                    nxt.append((e, b))
            segs = nxt
        self._wall_windows.append((t0, t1))
        self._wall_windows.sort()
        merged = []
        for s, e in self._wall_windows:        # compact
            if merged and s <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], e))
            else:
                merged.append((s, e))
        # bound the list: out-of-order realization is bounded by the
        # outstanding PendingResults, so ancient windows can be dropped —
        # a pathologically stale realization then at worst over-counts a
        # little wall, it never corrupts unboundedly
        self._wall_windows = merged[-_MAX_WALL_WINDOWS:]
        self.metrics.record_wall(sum(b - a for a, b in segs), events)

    def bucket_for(self, n_events: int) -> int:
        """Smallest bucket holding ``n_events`` (largest if none do)."""
        from repro.kernels import autotune
        return autotune.bucket_for(self.bucket_sizes, n_events)

    def warm(self, buckets=None) -> None:
        """Pre-compile (and pre-run once) the given buckets — compile cost
        paid before traffic arrives, not on the first unlucky request."""
        for b in buckets if buckets is not None else self.bucket_sizes:
            jax.block_until_ready(
                self.compiled_for(b)(jnp.asarray(self.workload.placeholder(b))))

    # -- inference ----------------------------------------------------------

    def _pad(self, x: np.ndarray, bucket: int) -> np.ndarray:
        return self.workload.pad(x, bucket)

    def infer(self, x, *, record: bool = True, sync: bool = True,
              timeout_s: float | None = None, bucket: int | None = None):
        """Serve ``x`` (n, ...): pad to bucket, dispatch, slice back.

        Requests larger than the top bucket are chunked through it; chunk
        k+1's pad + dispatch overlaps chunk k's compute, with at most
        :data:`MAX_INFLIGHT_CHUNKS` dispatches outstanding so an
        arbitrarily large request keeps bounded device memory (the old
        block-per-chunk loop pinned exactly one buffer; this pins a small
        pipeline's worth).

        ``sync=True`` (default) blocks and returns the logits array;
        ``sync=False`` returns a :class:`PendingResult` immediately after
        dispatch, letting the caller (e.g. a batcher loop) overlap the
        next flush with this one's in-flight compute.  Metrics are
        recorded when the result is realized, never on dispatch.
        ``timeout_s`` arms the realization watchdog (sync path only;
        async callers pass it to ``PendingResult.result``).
        ``bucket`` PINS the compile bucket instead of resolving it from
        the row count — the sentinel's canaries use this to ride a
        specific bucket's cached callable with a small probe batch.
        """
        x = np.asarray(x)
        pin = bucket
        if pin is not None:
            if pin not in self.bucket_sizes:
                raise ValueError(
                    f"pinned bucket {pin} not in ladder {self.bucket_sizes}")
            if x.shape[0] > pin:
                raise ValueError(
                    f"request of {x.shape[0]} rows cannot ride pinned "
                    f"bucket {pin}")
        top = self.bucket_sizes[-1]
        chunks = []
        for i in range(0, x.shape[0], top):
            if len(chunks) >= MAX_INFLIGHT_CHUNKS:
                # throttle: wait for the oldest in-flight chunk before
                # enqueueing more (its latency is still stamped at
                # realization, where the wait is then a no-op)
                jax.block_until_ready(chunks[-MAX_INFLIGHT_CHUNKS][0])
            chunk = x[i:i + top]
            n_valid = chunk.shape[0]
            bucket = self.bucket_for(n_valid) if pin is None else pin
            if self.injector is not None:
                self.injector.check("dispatch", path=self.workload.name,
                                    bucket=bucket)
                chunk = self.injector.corrupt_input(
                    chunk, path=self.workload.name, bucket=bucket)
            fn = self.compiled_for(bucket)
            t0 = time.perf_counter()
            out = fn(jnp.asarray(self._pad(chunk, bucket)))   # async dispatch
            if self.injector is not None:
                out = self.injector.wrap_output(out, path=self.workload.name,
                                                bucket=bucket)
            chunks.append((out, n_valid, bucket, t0))
        pending = PendingResult(self, chunks, record=record)
        return pending.result(timeout_s=timeout_s) if sync else pending

    def run_plan(self, plan, *, sync: bool = True):
        """Execute one :class:`~repro.serving.batcher.BatchPlan`; returns
        ``{rid: (n_i, ...) outputs}`` reassembled per request.

        ``sync=False`` returns a :class:`PendingPlan` right after
        dispatch; realize it with ``.result()`` once the next plans are
        in flight."""
        pending = PendingPlan(self.infer(plan.x, sync=False), plan.requests)
        return pending.result() if sync else pending

    def run_stream(self, stream, *, warmup: int = 2) -> dict:
        """Pump a fixed-size batch stream through the double-buffered feed
        loop (the trigger CLI's hot path).  All batches must share one
        size; each is padded to its ladder bucket before dispatch."""
        stream = list(stream)
        if not stream:
            return {"latencies": [], "events": 0, "wall_s": 0.0,
                    "bucket": None, "kgps": float("nan")}
        sizes = {b.shape[0] for b in stream}
        if len(sizes) != 1:
            raise ValueError(f"stream batches differ in size: {sorted(sizes)}")
        n_valid = sizes.pop()
        if n_valid > self.bucket_sizes[-1]:
            raise ValueError(
                f"stream batch size {n_valid} exceeds the top bucket "
                f"{self.bucket_sizes[-1]}; build the engine with "
                f"max_batch >= {n_valid} or chunk through infer()")
        bucket = self.bucket_for(n_valid)
        fwd = self.compiled_for(bucket)
        padded = [self._pad(np.asarray(b), bucket) for b in stream]
        lat, _, wall = serve_stream(fwd, padded, warmup=warmup)
        # KGPS counts VALID events only — padding rows are not throughput.
        events = n_valid * len(lat)
        for t in lat:
            self.metrics.record_batch(t, n_valid, bucket)
        self.metrics.record_wall(wall, events)
        return {"latencies": lat, "events": events, "wall_s": wall,
                "bucket": bucket, "kgps": kgps(events, wall)}
