"""Deterministic fault injection at the serving engine's seams.

A Level-1 trigger pipeline is judged by how it behaves when things go
wrong: the real-time trigger literature (arXiv 2307.07289) treats
continuous degraded operation as a first-class requirement, and you
cannot claim "the engine demotes on a compile failure" without a way to
*cause* a compile failure on demand, on CPU, in a unit test.  This
module is that way.

A :class:`FaultInjector` is handed to :class:`~repro.serving.engine.
ServingEngine` (and through it to :class:`~repro.serving.resilient.
ResilientEngine`).  The engine calls the injector at well-defined seams
of its dispatch path; an armed :class:`Fault` matching that seam fires
there.  Everything is deterministic — faults are armed with explicit
``times`` budgets and matched by (seam, path, bucket), never by random
draw — so every degraded-mode transition (demote, probe, re-promote,
shed, watchdog timeout) is reproducible in CI.

Seams
-----
``compile``
    Fires inside ``ServingEngine.compiled_for`` on a cache MISS (a warm
    cache never recompiles, so neither can it re-fail).  Models a
    Mosaic/XLA lowering failure on a new bucket shape.
``dispatch``
    Fires in ``ServingEngine.infer`` just before the chunk is handed to
    the compiled callable.  Models a runtime dispatch exception
    (device OOM, donated-buffer reuse, ...).
``input_nan``
    Overwrites the chunk's first event with NaNs before dispatch.
    Models path-local data corruption (a bad quantization scale, a DMA
    bit-flip) — scoped to one path, so the fallback rung still serves
    clean outputs.
``output_nan``
    Replaces the dispatched output with NaNs.  Models a kernel
    numerics bug: outputs come back shaped but non-finite.
``latency``
    Sleeps ``delay_s`` at dispatch.  Models a slow rung (preempted
    core, thermally throttled part) for deadline/backpressure drills.
``stuck``
    Wraps the output in a :class:`StuckBuffer` that only becomes ready
    after ``delay_s``.  Models a hung dispatch — the seam the engine's
    watchdog (``PendingResult.result(timeout_s=...)``) exists for.

Silent seams
------------
The seams above all trip a PR-6 detector: an exception, a NaN, or a
watchdog timeout.  The three **silent** seams below produce *finite,
shaped, wrong* answers — the failure mode a Level-1 trigger fears most,
because ``health()`` keeps reading ``healthy`` while physics is being
misclassified.  They exist to prove that gap (no PR-6 detector fires)
and to prove the sentinel (:mod:`repro.serving.sentinel`) closes it.
All three fire at the compile-cache BUILD seam: corruption lands in the
cached callable, persists across dispatches (like a corrupted weight in
HBM or a poisoned cache entry), and is only cleared by rebuilding the
entry (``ExecutionCore.evict`` — which is exactly what the sentinel's
quarantine does).

``scale_drift``
    Multiplies every int8 quantization scale (``"w_scale"`` leaf) by
    ``factor`` before the bucket's callable is built.  Models a drifted
    or corrupted dequantization scale: logits come back finite and
    plausibly shaped, just wrong.  A no-op on paths without quantized
    params (nothing to drift — the fault does not fire).
``weight_corrupt``
    Corrupts the first weight tensor (``"w"`` leaf): sign-flipped for
    integer (quantized) tensors, scaled by ``factor`` for floats.
    Models an SEU/HBM bit-flip class corruption of a cached param.
``stale_cache``
    Wraps the freshly built callable in :class:`StaleCacheFn`, which
    returns the PREVIOUS dispatch's output for every call after the
    first.  Models a stale/aliased compile-cache entry: answers are
    real logits — for somebody else's events.

Every firing is appended to :attr:`FaultInjector.log` as
``(seam, path, bucket)`` so tests can assert exactly which seams fired.
"""

from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

#: Seams whose firing trips a PR-6 detector (exception / NaN / timeout).
LOUD_SEAMS = ("compile", "dispatch", "input_nan", "output_nan", "latency",
              "stuck")

#: Seams that produce finite wrong answers no PR-6 detector sees — the
#: sentinel's coverage target.  All fire at the compile-cache build.
SILENT_SEAMS = ("scale_drift", "weight_corrupt", "stale_cache")

SEAMS = LOUD_SEAMS + SILENT_SEAMS


class InjectedFault(RuntimeError):
    """Raised by the ``compile`` / ``dispatch`` seams when a fault fires.

    Carries the seam so the resilience layer can classify the failure
    (and tests can assert the transition it caused) without string
    matching."""

    def __init__(self, seam: str, path=None, bucket=None):
        self.seam = seam
        self.path = path
        self.bucket = bucket
        super().__init__(
            f"injected {seam} fault (path={path!r}, bucket={bucket})")


@dataclasses.dataclass
class Fault:
    """One armed fault: where it fires, how often, how hard.

    ``path`` / ``bucket`` of ``None`` match any path / bucket.  ``times``
    is the firing budget — after that many firings the fault disarms
    itself, which is how tests script "fail once, then recover".
    """

    seam: str
    path: str | None = None
    bucket: int | None = None
    times: float = math.inf
    delay_s: float = 0.0
    factor: float = 2.0          # corruption magnitude (silent seams)
    fired: int = 0

    def __post_init__(self):
        if self.seam not in SEAMS:
            raise ValueError(f"unknown seam {self.seam!r}; one of {SEAMS}")

    @property
    def armed(self) -> bool:
        return self.fired < self.times

    def matches(self, seam: str, path, bucket) -> bool:
        return (self.armed and self.seam == seam
                and (self.path is None or self.path == path)
                and (self.bucket is None or self.bucket == bucket))


def drift_scales(params, factor: float):
    """``scale_drift``: every ``"w_scale"`` leaf multiplied by ``factor``.

    Returns the corrupted pytree copy, or ``params`` UNCHANGED (same
    object) when there is nothing to drift — the caller uses identity to
    decide whether the fault actually applies to this workload.
    """
    hits = [0]

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "w_scale":
                    out[k] = v * factor
                    hits[0] += 1
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    corrupted = walk(params)
    return corrupted if hits[0] else params


def corrupt_weight(params, factor: float):
    """``weight_corrupt``: the first ``"w"`` tensor, silently wrong.

    Integer (quantized) tensors are sign-flipped — dtype-preserving, so
    the int8 kernel contract still holds and nothing raises; float
    tensors are scaled by ``factor``.  Returns ``params`` unchanged
    (same object) when no weight leaf exists.
    """
    hit = [False]

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "w" and not hit[0]:
                    hit[0] = True
                    out[k] = -v if np.issubdtype(
                        np.dtype(v.dtype), np.integer) else v * factor
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    corrupted = walk(params)
    return corrupted if hit[0] else params


class StaleCacheFn:
    """``stale_cache``: a compiled callable serving yesterday's answers.

    The first call passes through (nothing stale exists yet); every call
    after returns the PREVIOUS call's output while quietly computing and
    retaining the current one.  All calls to one cache entry share a
    padded bucket shape, so the swap is shape-safe — the caller receives
    real, finite logits that belong to somebody else's events.
    """

    def __init__(self, fn):
        self._fn = fn
        self._last = None

    def __call__(self, x):
        cur = self._fn(x)
        if self._last is None:
            self._last = cur
            return cur
        out, self._last = self._last, cur
        return out


class StuckBuffer:
    """A dispatch result that refuses to become ready until ``ready_at``.

    Duck-types the slice of the jax.Array surface the engine's
    realization path touches — ``is_ready()`` (polled by the watchdog),
    ``block_until_ready()`` (the legacy blocking path; sleeps out the
    remaining stall so non-watchdog callers still terminate), and
    ``__array__`` / ``shape`` / ``dtype`` for host materialization.
    """

    def __init__(self, inner, ready_at: float, clock=time.monotonic):
        self._inner = inner
        self._ready_at = ready_at
        self._clock = clock

    def is_ready(self) -> bool:
        return self._clock() >= self._ready_at

    def block_until_ready(self):
        while not self.is_ready():
            time.sleep(min(0.001, 0.25))
        return self

    def __array__(self, dtype=None, copy=None):
        arr = np.asarray(self._inner)
        return arr.astype(dtype) if dtype is not None else arr

    @property
    def shape(self):
        return self._inner.shape

    @property
    def dtype(self):
        return self._inner.dtype

    def __getitem__(self, idx):
        return np.asarray(self)[idx]


class FaultInjector:
    """Holds armed :class:`Fault`\\ s; the engine consults it at seams.

    One injector can be shared by every engine in a degradation ladder
    (the :class:`~repro.serving.resilient.ResilientEngine` threads
    itself through) — path-scoped faults then hit exactly the rung they
    name, which is what makes "primary fails, fallback serves"
    testable.
    """

    def __init__(self, clock=time.monotonic):
        self._clock = clock
        self.faults: list[Fault] = []
        self.log: list[tuple] = []       # (seam, path, bucket) per firing

    # -- arming ------------------------------------------------------------

    def arm(self, seam: str, *, path: str | None = None,
            bucket: int | None = None, times: float = math.inf,
            delay_s: float = 0.0, factor: float = 2.0) -> Fault:
        fault = Fault(seam=seam, path=path, bucket=bucket, times=times,
                      delay_s=delay_s, factor=factor)
        self.faults.append(fault)
        return fault

    def disarm(self, fault: Fault | None = None) -> None:
        """Remove one fault (or all of them)."""
        if fault is None:
            self.faults.clear()
        else:
            self.faults.remove(fault)

    def fired(self, seam: str | None = None) -> int:
        """Total firings, optionally restricted to one seam."""
        return sum(1 for s, _, _ in self.log if seam is None or s == seam)

    # -- seams (called by the engine) --------------------------------------

    def _fire(self, seam: str, path, bucket) -> Fault | None:
        for f in self.faults:
            if f.matches(seam, path, bucket):
                f.fired += 1
                self.log.append((seam, path, bucket))
                return f
        return None

    def check(self, seam: str, *, path=None, bucket=None) -> None:
        """``compile`` / ``dispatch`` seam: raise when a fault fires."""
        if self._fire(seam, path, bucket) is not None:
            raise InjectedFault(seam, path=path, bucket=bucket)

    def corrupt_build(self, workload, bucket):
        """``scale_drift`` / ``weight_corrupt`` seams, consulted by
        ``ExecutionCore.compiled_for`` on a cache MISS.

        When an armed silent fault matches and the workload can actually
        be corrupted that way (it exposes a ``corrupted(seam, factor)``
        hook returning a poisoned twin callable, and the corruption
        found something to bite), returns the corrupted compiled
        callable; otherwise ``None`` and the build proceeds normally.
        A fault that does not apply (e.g. ``scale_drift`` on an fp32
        path) neither fires nor burns budget.
        """
        path = getattr(workload, "name", None)
        hook = getattr(workload, "corrupted", None)
        if hook is None:
            return None
        for seam in ("scale_drift", "weight_corrupt"):
            for f in self.faults:
                if f.matches(seam, path, bucket):
                    fn = hook(seam, f.factor, bucket)
                    if fn is not None:
                        f.fired += 1
                        self.log.append((seam, path, bucket))
                        return fn
        return None

    def wrap_stale(self, fn, *, path=None, bucket=None):
        """``stale_cache`` seam: wrap a freshly built cache entry in
        :class:`StaleCacheFn` (previous dispatch's output) when armed."""
        if self._fire("stale_cache", path, bucket) is not None:
            return StaleCacheFn(fn)
        return fn

    def corrupt_input(self, x, *, path=None, bucket=None):
        """``input_nan`` seam: NaN the first event of the chunk."""
        if self._fire("input_nan", path, bucket) is not None:
            x = np.array(x, copy=True)
            x[0] = np.nan
        return x

    def wrap_output(self, out, *, path=None, bucket=None):
        """``output_nan`` / ``stuck`` / ``latency`` seams, applied to the
        freshly dispatched (un-realized) result."""
        f = self._fire("latency", path, bucket)
        if f is not None:
            time.sleep(f.delay_s)
        f = self._fire("output_nan", path, bucket)
        if f is not None:
            return np.full(out.shape, np.nan, np.float32)
        f = self._fire("stuck", path, bucket)
        if f is not None:
            return StuckBuffer(out, self._clock() + f.delay_s, self._clock)
        return out
