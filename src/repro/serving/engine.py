"""Sharded trigger inference engine over the JEDI-net forward paths.

The serving-tier counterpart of the paper's FPGA trigger pipeline: one
object owning everything between "a batch of events exists on the host"
and "logits are ready", for ANY registered forward path
(:mod:`repro.core.paths`).

Since the fabric split, the generic machinery — warm compile cache,
pad-to-bucket dispatch, async :class:`~repro.serving.core.PendingResult`
in-flight window, watchdog, overlap-safe wall-union KGPS accounting,
fault seams — lives in :class:`~repro.serving.core.ExecutionCore` and is
shared with every other workload (LM decode, recsys).  This module adds
only what is trigger-specific:

* **data-parallel sharding** — the batch axis is ``shard_map``-ped over
  the local device mesh (``launch/mesh.make_host_mesh``); each device
  runs the whole fused kernel on its batch slice, the serving analogue
  of replicating the FPGA pipeline per link.  On one device the wrapper
  collapses to a plain ``jit``.
* **PathSpec resolution** — forward fn, Pallas-ness, params transform
  (e.g. int8 quantization), supported compute dtypes, VMEM working set
  for the bucket ladder, roofline level are all read off the path's
  :class:`~repro.core.paths.PathSpec`; registering a new path makes it
  servable with no engine edits.
* **per-path bucket ladder** — buckets come from
  ``spec.bucket_ladder`` scaled to the mesh, so quantized paths (int8
  weights resident at 1 B/element) earn deeper ladders with no engine
  knowledge of why.

:class:`TriggerWorkload` is the :class:`~repro.serving.core.Workload`
declaration; :class:`ServingEngine` composes it with the core and keeps
the historical engine API (``infer`` / ``run_plan`` / ``run_stream`` /
``warm`` / ``roofline``).
"""

from __future__ import annotations

import copy
import functools

import jax
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.core import paths as forward_paths
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import shard_map_compat
from repro.serving.core import (  # noqa: F401  (re-exported: historical home)
    MAX_INFLIGHT_CHUNKS,
    ExecutionCore,
    PendingPlan,
    PendingResult,
    WatchdogTimeout,
    Workload,
    serve_stream,
)
from repro.serving import faults
from repro.serving.metrics import ServingMetrics


class TriggerWorkload(Workload):
    """Jet-classification over one forward path, sharded over the mesh.

    The :class:`~repro.serving.core.Workload` declaration for the
    paper's trigger tier: dense ``(batch, N_o, P)`` event batches through
    a registered :class:`~repro.core.paths.PathSpec`, data-parallel over
    the local device mesh.
    """

    def __init__(self, params, cfg, *, forward: str = "fused_full",
                 interpret: bool | None = None, mesh="auto"):
        self.spec = forward_paths.get(forward)   # raises listing choices
        if not self.spec.supports_dtype(cfg.compute_dtype):
            raise ValueError(
                f"path {forward!r} supports compute dtypes "
                f"{self.spec.compute_dtypes}, not {cfg.compute_dtype!r}")
        # the spec's params transform (e.g. int8 quantization) runs ONCE,
        # here — every dispatch then serves the transformed weights
        self.params = self.spec.prepare_params(params)
        self.cfg = cfg
        self.name = forward
        # compiled Pallas needs a real TPU; fall back to interpret elsewhere
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret) and self.spec.pallas
        if mesh == "auto":
            mesh = make_host_mesh() if len(jax.devices()) > 1 else None
        self.mesh = mesh
        self.n_shards = int(np.prod(mesh.devices.shape)) if mesh else 1

    def bucket_ladder(self, max_batch: int) -> list[int]:
        # ceil so the top rung still covers max_batch after the
        # per-device ladder is scaled back up by the shard count.
        # The ladder is the PATH'S policy (spec.bucket_ladder):
        # per-sample working set AND weight-residency reservation
        # both come off the spec, so quantized paths (int8 weights
        # resident at 1 B/element) earn deeper ladders here with no
        # fabric knowledge of why.
        per_dev = -(-max_batch // self.n_shards)
        ladder = self.spec.bucket_ladder(self.cfg, self.params, per_dev)
        return [b * self.n_shards for b in ladder]

    def validate_buckets(self, bucket_sizes) -> None:
        if self.mesh is not None:
            bad = [b for b in bucket_sizes if b % self.n_shards]
            if bad:
                raise ValueError(
                    f"buckets {bad} do not divide the {self.n_shards}-way "
                    "data mesh")

    def cache_key(self, bucket) -> tuple:
        c = self.cfg
        return (self.name, int(bucket), c.n_objects, c.n_features,
                c.compute_dtype, self.interpret, self.n_shards)

    def build(self, bucket=None):
        fn = self.spec.forward
        if self.spec.pallas:
            fn = functools.partial(fn, interpret=self.interpret)
        cfg = self.cfg

        def call(params, x):
            return fn(params, cfg, x)

        if self.mesh is not None:
            call = shard_map_compat(call, self.mesh,
                                    in_specs=(P(), P("data")),
                                    out_specs=P("data"))
        return jax.jit(functools.partial(call, self.params))

    def placeholder(self, bucket: int) -> np.ndarray:
        c = self.cfg
        return np.zeros((bucket, c.n_objects, c.n_features), np.float32)

    def corrupted(self, seam: str, factor: float, bucket):
        # Silent fault seams: rebuild the bucket's compiled fn from
        # corrupted params.  Returning None means "does not apply"
        # (e.g. scale_drift on an fp32 path with no w_scale leaves),
        # and the armed fault keeps its budget.
        if seam == "scale_drift":
            bad = faults.drift_scales(self.params, factor)
        elif seam == "weight_corrupt":
            bad = faults.corrupt_weight(self.params, factor)
        else:
            return None
        if bad is self.params:
            return None
        twin = copy.copy(self)
        twin.params = bad
        return twin.build(bucket)


class ServingEngine(ExecutionCore):
    """Bucketed, sharded, metered inference over one forward path —
    the trigger instantiation of the execution core."""

    def __init__(self, params, cfg, *, forward: str = "fused_full",
                 interpret: bool | None = None, mesh="auto",
                 bucket_sizes=None, max_batch: int = 1024,
                 metrics: ServingMetrics | None = None, injector=None):
        super().__init__(
            TriggerWorkload(params, cfg, forward=forward,
                            interpret=interpret, mesh=mesh),
            bucket_sizes=bucket_sizes, max_batch=max_batch,
            metrics=metrics, injector=injector)

    # -- trigger-workload surface (historical engine API) -------------------

    @property
    def spec(self):
        return self.workload.spec

    @property
    def params(self):
        return self.workload.params

    @property
    def cfg(self):
        return self.workload.cfg

    @property
    def forward(self) -> str:
        return self.workload.name

    @property
    def interpret(self) -> bool:
        return self.workload.interpret

    @property
    def mesh(self):
        return self.workload.mesh

    @property
    def n_shards(self) -> int:
        return self.workload.n_shards

    def _build(self):
        return self.workload.build()

    # -- roofline context ----------------------------------------------------

    def roofline(self, buckets=None, *, compute_bytes: int = 2) -> dict:
        """TPUModel step-time context per bucket, at the spec's declared
        fusion level and weight precision."""
        return self.spec.roofline_for(
            self.cfg, buckets if buckets is not None else self.bucket_sizes,
            compute_bytes=compute_bytes, chips=max(self.n_shards, 1))
