"""Sharded inference engine over the JEDI-net forward paths.

The serving-tier counterpart of the paper's FPGA trigger pipeline: one
object owning everything between "a batch of events exists on the host"
and "logits are ready", for ANY ``FORWARD_FNS`` path:

* **data-parallel sharding** — the batch axis is ``shard_map``-ped over
  the local device mesh (``launch/mesh.make_host_mesh``); each device
  runs the whole fused kernel on its batch slice, the serving analogue
  of replicating the FPGA pipeline per link.  On one device the wrapper
  collapses to a plain ``jit``.
* **warm compile cache** — callables are cached per
  (path, bucket, event shape, dtype).  Requests are padded up to ladder
  buckets (:func:`repro.kernels.autotune.bucket_ladder`), so arbitrary
  request counts reuse a handful of compilations and padding never
  forces a tile-degenerate recompile.
* **double-buffered device feed** — :func:`serve_stream` overlaps the
  next batch's host->device transfer with the current batch's compute
  (the host-boundary analogue of the paper's ping-pong buffers between
  pipeline stages).
* **rolling accounting** — every dispatch lands in a shared
  :class:`~repro.serving.metrics.ServingMetrics` (p50/p99/KGPS), with
  padding rows excluded from event counts.

Roofline context per bucket comes from
:func:`repro.core.codesign.bucket_roofline` so reported wall-clock
always sits next to what the TPU model says the step should cost.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.core import codesign
from repro.core.interaction_net import FORWARD_FNS
from repro.kernels import autotune
from repro.kernels.fused_jedinet.autotune import full_forward_bytes_per_sample
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import shard_map_compat
from repro.serving.metrics import ServingMetrics, kgps

# Paths that are Pallas kernels (need interpret=... off-TPU).
PALLAS_PATHS = ("fused", "fused_full")


def serve_stream(fwd, stream, *, warmup: int = 2, metrics=None, bucket=None):
    """Double-buffered device-feed loop; returns per-batch latencies.

    ``fwd`` must be an async-dispatch callable (jitted) taking a host or
    device array; latencies are seconds from host handoff to
    logits-ready.  Batch k+1's ``device_put`` is issued while batch k is
    still computing, so H2D transfer hides behind compute.  The first
    ``warmup`` batches (compile + cache warm) are excluded from stats;
    a stream no longer than ``warmup`` yields empty stats, not a crash.

    When ``metrics`` is given every post-warmup batch is recorded there
    (``bucket`` labels the records; defaults to the batch row count).
    """
    latencies = []
    events = 0
    it = iter(stream)

    # prime the pipeline: first transfer issued before the loop body
    try:
        nxt = jax.device_put(next(it))
    except StopIteration:
        return latencies, events, 0.0

    # wall time starts at the last warmup batch; with no warmup it starts
    # here, so KGPS is well-defined for any stream length
    t_start = time.perf_counter() if warmup == 0 else None
    k = 0
    while nxt is not None:
        cur = nxt
        t0 = time.perf_counter()
        out = fwd(cur)                      # async dispatch
        try:
            nxt = jax.device_put(next(it))  # overlap next H2D with compute
        except StopIteration:
            nxt = None
        jax.block_until_ready(out)
        t1 = time.perf_counter()
        k += 1
        if k <= warmup:                     # exclude compile from stats
            t_start = time.perf_counter()
            continue
        latencies.append(t1 - t0)
        events += cur.shape[0]
        if metrics is not None:
            metrics.record_batch(t1 - t0, cur.shape[0],
                                 bucket or cur.shape[0])
    wall = (time.perf_counter() - t_start) if t_start else 0.0
    return latencies, events, wall


class ServingEngine:
    """Bucketed, sharded, metered inference over one forward path."""

    def __init__(self, params, cfg, *, forward: str = "fused_full",
                 interpret: bool | None = None, mesh="auto",
                 bucket_sizes=None, max_batch: int = 1024,
                 metrics: ServingMetrics | None = None):
        if forward not in FORWARD_FNS:
            raise ValueError(f"unknown forward path {forward!r}")
        self.params = params
        self.cfg = cfg
        self.forward = forward
        # compiled Pallas needs a real TPU; fall back to interpret elsewhere
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret) and forward in PALLAS_PATHS
        if mesh == "auto":
            mesh = make_host_mesh() if len(jax.devices()) > 1 else None
        self.mesh = mesh
        self.n_shards = int(np.prod(mesh.devices.shape)) if mesh else 1
        self.metrics = metrics if metrics is not None else ServingMetrics()

        if bucket_sizes is None:
            # ceil so the top rung still covers max_batch after the
            # per-device ladder is scaled back up by the shard count
            per_dev = -(-max_batch // self.n_shards)
            ladder = autotune.bucket_ladder(
                per_dev, self._per_sample_bytes())
            bucket_sizes = [b * self.n_shards for b in ladder]
        self.bucket_sizes = sorted(int(b) for b in bucket_sizes)
        if self.mesh is not None:
            bad = [b for b in self.bucket_sizes if b % self.n_shards]
            if bad:
                raise ValueError(
                    f"buckets {bad} do not divide the {self.n_shards}-way "
                    "data mesh")
        self._cache: dict[tuple, object] = {}

    # -- compile-cache management ------------------------------------------

    def _per_sample_bytes(self) -> int:
        c = self.cfg
        return full_forward_bytes_per_sample(
            c.n_objects, c.n_features,
            autotune.mlp_widths(self.params["fr"]),
            autotune.mlp_widths(self.params["fo"]),
            autotune.mlp_widths(self.params["phi"]))

    def _cache_key(self, bucket: int) -> tuple:
        c = self.cfg
        return (self.forward, int(bucket), c.n_objects, c.n_features,
                c.compute_dtype, self.interpret, self.n_shards)

    def compiled_for(self, bucket: int):
        """The cached jitted callable for one bucket shape (built on miss)."""
        key = self._cache_key(bucket)
        fn = self._cache.get(key)
        if fn is None:
            fn = self._build()
            self._cache[key] = fn
        return fn

    def _build(self):
        fn = FORWARD_FNS[self.forward]
        if self.forward in PALLAS_PATHS:
            fn = functools.partial(fn, interpret=self.interpret)
        cfg = self.cfg

        def call(params, x):
            return fn(params, cfg, x)

        if self.mesh is not None:
            call = shard_map_compat(call, self.mesh,
                                    in_specs=(P(), P("data")),
                                    out_specs=P("data"))
        return jax.jit(functools.partial(call, self.params))

    @property
    def cache_size(self) -> int:
        return len(self._cache)

    def bucket_for(self, n_events: int) -> int:
        """Smallest bucket holding ``n_events`` (largest if none do)."""
        return autotune.bucket_for(self.bucket_sizes, n_events)

    def warm(self, buckets=None) -> None:
        """Pre-compile (and pre-run once) the given buckets — compile cost
        paid before traffic arrives, not on the first unlucky request."""
        c = self.cfg
        for b in buckets if buckets is not None else self.bucket_sizes:
            x = np.zeros((b, c.n_objects, c.n_features), np.float32)
            jax.block_until_ready(self.compiled_for(b)(jnp.asarray(x)))

    # -- inference ----------------------------------------------------------

    def _pad(self, x: np.ndarray, bucket: int) -> np.ndarray:
        n = x.shape[0]
        if n == bucket:
            return x
        return np.concatenate(
            [x, np.zeros((bucket - n, *x.shape[1:]), x.dtype)], axis=0)

    def infer(self, x, *, record: bool = True) -> np.ndarray:
        """Classify ``x`` (n, N_o, P): pad to bucket, dispatch, slice back.

        Requests larger than the top bucket are chunked through it.
        """
        x = np.asarray(x)
        top = self.bucket_sizes[-1]
        outs = []
        for i in range(0, x.shape[0], top):
            chunk = x[i:i + top]
            bucket = self.bucket_for(chunk.shape[0])
            fn = self.compiled_for(bucket)
            t0 = time.perf_counter()
            out = fn(jnp.asarray(self._pad(chunk, bucket)))
            jax.block_until_ready(out)
            t1 = time.perf_counter()
            if record:
                self.metrics.record_batch(t1 - t0, chunk.shape[0], bucket)
                self.metrics.record_wall(t1 - t0, chunk.shape[0])
            outs.append(np.asarray(out)[:chunk.shape[0]])
        return np.concatenate(outs, axis=0)

    def run_plan(self, plan) -> dict:
        """Execute one :class:`~repro.serving.batcher.BatchPlan`; returns
        ``{rid: (n_i, n_targets) logits}`` reassembled per request."""
        logits = self.infer(plan.x)
        out: dict[int, list] = {}
        for rid, start, stop in plan.requests:
            out.setdefault(rid, []).append(logits[start:stop])
        return {rid: np.concatenate(parts, axis=0)
                for rid, parts in out.items()}

    def run_stream(self, stream, *, warmup: int = 2) -> dict:
        """Pump a fixed-size batch stream through the double-buffered feed
        loop (the trigger CLI's hot path).  All batches must share one
        size; each is padded to its ladder bucket before dispatch."""
        stream = list(stream)
        if not stream:
            return {"latencies": [], "events": 0, "wall_s": 0.0,
                    "bucket": None, "kgps": float("nan")}
        sizes = {b.shape[0] for b in stream}
        if len(sizes) != 1:
            raise ValueError(f"stream batches differ in size: {sorted(sizes)}")
        n_valid = sizes.pop()
        bucket = self.bucket_for(n_valid)
        fwd = self.compiled_for(bucket)
        padded = [self._pad(np.asarray(b), bucket) for b in stream]
        lat, _, wall = serve_stream(fwd, padded, warmup=warmup)
        # KGPS counts VALID events only — padding rows are not throughput.
        events = n_valid * len(lat)
        for t in lat:
            self.metrics.record_batch(t, n_valid, bucket)
        self.metrics.record_wall(wall, events)
        return {"latencies": lat, "events": events, "wall_s": wall,
                "bucket": bucket, "kgps": kgps(events, wall)}

    # -- roofline context ----------------------------------------------------

    def roofline(self, buckets=None, *, compute_bytes: int = 2) -> dict:
        """TPUModel step-time context per bucket for this path's level."""
        level = codesign.PATH_FUSED_LEVELS.get(self.forward, "none")
        return codesign.bucket_roofline(
            self.cfg, buckets if buckets is not None else self.bucket_sizes,
            fused=level, compute_bytes=compute_bytes,
            chips=max(self.n_shards, 1))
