"""Online silent-corruption sentinel: canaries, shadows, quarantine.

The degradation ladder (:mod:`repro.serving.resilient`) catches loud
failures — exceptions, NaN logits, watchdog timeouts.  It is blind to
the failure mode a Level-1 trigger fears most: *finite wrong answers*.
A drifted int8 ``w_scale``, a corrupted weight tensor, or a stale
compile-cache entry (the silent seams of :mod:`repro.serving.faults`)
produces logits that are shaped, finite, and wrong — ``health()`` reads
``healthy`` while physics is misclassified.  The sentinel is the online
correctness layer that closes that gap, with three mechanisms:

**Golden canaries.**  At construction the sentinel draws one small
fixed canary batch and precomputes *golden* logits per constructible
chain rung from the rung spec's own ``ref`` fn (the registry's
numerical oracle).  On a request-count / time cadence — and on the
FIRST request a bucket ever serves — the canary batch is injected
through the *live* serve path (pinned to the bucket's compiled
callable via ``infer(bucket=...)``, so a 4-event probe exercises the
big bucket's cache entry) and compared against the golden logits
within ``tolerance_slack x PathSpec.tolerance``.  Build-time
corruption is therefore caught on the bucket's first canary — one
observed batch of detection latency.

**Shadow re-execution.**  A duty-cycled sample of live requests
(deterministic stride ``round(1/shadow_rate)`` — like the fault
injector, never a random draw) re-runs asynchronously on the chain's
terminal non-Pallas rung (:func:`repro.core.paths.terminal_rung`), the
one rung plain XLA guarantees servable.  Per-bucket agreement
statistics — EWMA max-|Δlogit| and argmax-disagreement rate — land in
:class:`~repro.serving.metrics.ServingMetrics` gauges.  The trip
threshold is calibrated from the golden table itself
(``slack x max(|golden[rung] - golden[terminal]|, tolerance)``) so a
quantized rung's legitimate quantization gap to the fp32 oracle never
trips it.  The worker thread only *records* trips; the serve thread
applies them at its next ``observe()`` — no cross-thread engine
mutation.

**Canary-gated quarantine.**  A sentinel trip evicts the poisoned
rung's compile-cache entry for that bucket (build-time corruption
lives in the cached callable — see ``FaultInjector.corrupt_build``),
demotes the bucket below the rung, and marks it ``quarantined``.
Unlike the loud ladder's single live probe, a quarantined rung only
re-promotes after ``promote_after`` CONSECUTIVE clean canaries, each
one exercising the rebuilt callable at the quarantined rung; a dirty
canary re-evicts and zeroes the streak.  ``health()`` reports the new
``quarantined`` state (worse than ``shedding``, better than ``down``)
with per-bucket detail.

The sentinel owns no wall clock: it reads time only through the
engine's injectable clock seam, so every cadence decision is
freezable in tests.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

from repro.core import paths as forward_paths


@dataclasses.dataclass
class SentinelConfig:
    """Knobs for one :class:`Sentinel`.

    ``canary_every`` is a per-bucket request-count cadence (the first
    request a bucket serves always canaries); ``canary_interval_s``
    optionally adds a time cadence on the engine's clock.
    ``shadow_rate`` is the duty cycle of terminal-rung shadow
    re-execution (0 disables it); ``shadow_sync`` runs shadow jobs
    inline on the serve thread — deterministic for tests, and what the
    post-stream verification uses.  ``promote_after`` is K, the clean
    canary streak a quarantined rung needs to re-promote.
    ``tolerance_slack`` scales ``PathSpec.tolerance`` into the canary
    trip threshold (live-vs-ref tolerances are tight; corruption is
    orders of magnitude away).
    """

    canary_every: int = 64
    canary_interval_s: float | None = None
    shadow_rate: float = 1 / 16
    shadow_sync: bool = False
    shadow_queue: int = 64
    promote_after: int = 3
    tolerance_slack: float = 8.0
    canary_events: int = 4
    ewma_alpha: float = 0.5
    seed: int = 0


class Sentinel:
    """Online correctness monitor bound to one ResilientEngine."""

    def __init__(self, engine, config: SentinelConfig | None = None, *,
                 clock=None):
        self.config = config if config is not None else SentinelConfig()
        self._engine = engine
        self._clock = clock if clock is not None else engine._clock
        cfg = engine.cfg
        # decorrelate the canary draw from common user seeds: live
        # traffic drawn from RandomState(0) must never alias the canary
        # batch, or a stale-cache entry replaying that traffic would
        # pass the canary by construction
        rng = np.random.RandomState((self.config.seed ^ 0xC0FFEE) & 0xFFFFFFFF)
        self._canary_x = rng.normal(
            0.0, 1.0, (self.config.canary_events, cfg.n_objects,
                       cfg.n_features)).astype(np.float32)
        self.terminal_level = len(engine.chain) - 1

        # golden logits per constructible rung, from the rung's own ref
        # fn on ITS prepared params (int8 rungs are compared against the
        # int8 oracle, so PathSpec.tolerance is the right yardstick)
        self._golden: dict[int, np.ndarray] = {}
        for lvl, name in enumerate(engine.chain):
            if lvl in engine._construct_failed:
                continue
            spec = forward_paths.get(name)
            try:
                prepared = spec.prepare_params(engine._params)
                self._golden[lvl] = np.asarray(
                    spec.ref(prepared, cfg, self._canary_x), np.float32)
            except Exception:   # noqa: BLE001 — a rung without a golden
                pass            # just cannot canary (counted per canary)

        # shadow trip threshold per rung: the rung's OWN legitimate gap
        # to the terminal oracle (e.g. int8 quantization loss), slacked
        golden_t = self._golden.get(self.terminal_level)
        self._shadow_thr: dict[int, float] = {}
        for lvl, g in self._golden.items():
            base = (float(np.abs(g - golden_t).max())
                    if golden_t is not None else 0.0)
            tol = forward_paths.get(engine.chain[lvl]).tolerance
            self._shadow_thr[lvl] = (
                self.config.tolerance_slack * max(base, tol))

        self._since: dict[int, int] = {}       # requests since last canary
        self._last_canary: dict[int, float] = {}
        self._shadow_count = 0
        self._ewma: dict[int, tuple[float, float]] = {}  # bucket -> (dev, arg)
        self._stats_lock = threading.Lock()
        self._pending: list[tuple[int, int]] = []        # (bucket, level)
        self._pending_lock = threading.Lock()
        self._queue: queue.Queue | None = None
        self._worker: threading.Thread | None = None

    # -- serve-thread surface ------------------------------------------------

    def observe(self, x, out, bucket: int, level: int) -> None:
        """One recorded live serve happened on ``bucket`` at ``level``.

        Called by the engine on the serve thread after a successful
        rung serve: applies any shadow-worker trips, duty-cycles the
        request into shadow re-execution, and runs the canary when the
        bucket's cadence is due."""
        self._apply_pending()
        if self._should_shadow(bucket, level):
            self._submit_shadow(np.asarray(x), np.asarray(out), bucket,
                                level)
        cnt = self._since.get(bucket, self.config.canary_every)
        due = cnt >= self.config.canary_every
        if not due and self.config.canary_interval_s is not None:
            last = self._last_canary.get(bucket)
            due = (last is None
                   or self._clock() - last >= self.config.canary_interval_s)
        if due:
            self.canary(bucket)
        else:
            self._since[bucket] = cnt + 1

    def canary(self, bucket: int) -> bool | None:
        """Inject the golden canary through ``bucket``'s live rung.

        Quarantined buckets canary their QUARANTINED rung (that is the
        requalification gate); healthy buckets canary the active rung.
        Returns True (clean), False (mismatch -> quarantine), or None
        (no golden / rung raised — loud failures are the ladder's job).
        """
        eng = self._engine
        st = eng._bucket_state(bucket)
        lvl = st.q_level if st.quarantined else st.level
        m = eng.metrics
        m.incr("canaries")
        self._since[bucket] = 0
        self._last_canary[bucket] = self._clock()
        golden = self._golden.get(lvl)
        if golden is None:
            m.incr("canary_errors")
            return None
        n = min(self._canary_x.shape[0], bucket)
        try:
            # no watchdog thread: the canary rides a rung that just
            # served a live request successfully (wedges trip the loud
            # ladder there), and the spawn costs ~0.3 ms per canary —
            # a third of the whole canary budget on fast paths
            live = eng._engine_for(lvl).infer(
                self._canary_x[:n], record=False, bucket=bucket)
        except Exception:   # noqa: BLE001 — loud canary failure: not a
            m.incr("canary_errors")   # silent trip, but never a clean pass
            if st.quarantined:
                st.clean = 0
            return None
        dev = float(np.abs(np.asarray(live, np.float32) - golden[:n]).max())
        m.gauge(f"canary_dev_b{bucket}", dev)
        tol = forward_paths.get(eng.chain[lvl]).tolerance
        if np.isfinite(dev) and dev <= self.config.tolerance_slack * tol:
            if st.quarantined:
                st.clean += 1
                if st.clean >= self.config.promote_after:
                    eng._requalify(bucket)
            return True
        m.incr("canary_mismatches")
        eng._quarantine(bucket, lvl)
        return False

    def verify_stream(self, stream, bucket: int, level: int) -> None:
        """Post-hoc sentinel pass over a served fixed-size stream.

        The double-buffered stream loop is the latency-critical path —
        it is left untouched.  After the stream returns, a duty-cycled
        sample of its ticks re-runs through the live rung's compiled
        callable and shadows against the terminal oracle (synchronously
        — the stream is already over, there is nothing to overlap), and
        the bucket canaries on its normal ``canary_every`` cadence with
        every tick counted as one observed request (a bucket's FIRST
        stream still always canaries, preserving the one-batch
        detection guarantee for build-time corruption; later short
        streams amortize the canary instead of each paying one).  This
        is the overhead the ≤5% stream budget in EXPERIMENTS.md
        §Sentinel measures: the elapsed verification wall lands in the
        ``sentinel_verify_s`` gauge so the benchmark can report it
        against the stream's wall."""
        t0 = self._clock()
        if self.config.shadow_rate > 0 and level < self.terminal_level:
            stride = max(1, int(round(1.0 / self.config.shadow_rate)))
            try:
                eng = self._engine._engine_for(level)
            except Exception:   # noqa: BLE001 — rung gone: canary only
                eng = None
            if eng is not None:
                for i in range(stride - 1, len(stream), stride):
                    x = np.asarray(stream[i])
                    try:
                        out = eng.infer(x, record=False)
                    except Exception:   # noqa: BLE001 — loud: ladder's job
                        continue
                    self._shadow_job(x, np.asarray(out), bucket, level)
        cnt = self._since.get(bucket, self.config.canary_every)
        for _ in range(len(stream)):
            cnt += 1
            if cnt >= self.config.canary_every:
                self.canary(bucket)
                cnt = 0
        self._since[bucket] = cnt
        self._apply_pending()
        self._engine.metrics.gauge("sentinel_verify_s", self._clock() - t0)

    def detail(self) -> dict:
        """Sentinel block for ``health()``."""
        with self._stats_lock:
            ewma = {b: {"dev": d, "argmax_disagree": a}
                    for b, (d, a) in sorted(self._ewma.items())}
        return {
            "canary_every": self.config.canary_every,
            "shadow_rate": self.config.shadow_rate,
            "promote_after": self.config.promote_after,
            "golden_rungs": sorted(self._golden),
            "shadow_ewma": ewma,
        }

    # -- shadow re-execution -------------------------------------------------

    def _should_shadow(self, bucket: int, level: int) -> bool:
        if self.config.shadow_rate <= 0 or level >= self.terminal_level:
            return False
        st = self._engine._state.get(bucket)
        if st is not None and st.quarantined:
            return False        # already caught; canaries gate recovery
        stride = max(1, int(round(1.0 / self.config.shadow_rate)))
        self._shadow_count += 1
        return self._shadow_count % stride == 0

    def _submit_shadow(self, x, out, bucket: int, level: int) -> None:
        if self.config.shadow_sync:
            self._shadow_job(x, out, bucket, level)
            return
        if self._worker is None:
            self._queue = queue.Queue(maxsize=self.config.shadow_queue)
            self._worker = threading.Thread(
                target=self._worker_loop, name="sentinel-shadow",
                daemon=True)
            self._worker.start()
        try:
            self._queue.put_nowait((np.array(x, copy=True),
                                    np.array(out, copy=True),
                                    bucket, level))
        except queue.Full:
            self._engine.metrics.incr("shadow_dropped")

    def _worker_loop(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item is None:
                    return
                self._shadow_job(*item)
            finally:
                self._queue.task_done()

    def _shadow_job(self, x, out, bucket: int, level: int) -> None:
        """Re-run ``x`` on the terminal rung; fold agreement stats into
        metrics; RECORD (never apply) a trip on disagreement beyond the
        rung's calibrated threshold."""
        m = self._engine.metrics
        m.incr("shadow_requests")
        try:
            ref = self._engine._engine_for(self.terminal_level).infer(
                x, record=False)
        except Exception:   # noqa: BLE001 — oracle unavailable: no verdict
            m.incr("shadow_errors")
            return
        ref = np.asarray(ref, np.float32)
        out = np.asarray(out, np.float32)
        dev = float(np.abs(out - ref).max())
        disagree = float(np.mean(np.argmax(out, axis=-1)
                                 != np.argmax(ref, axis=-1)))
        a = self.config.ewma_alpha
        with self._stats_lock:
            prev = self._ewma.get(bucket)
            ewma = ((dev, disagree) if prev is None else
                    (a * dev + (1 - a) * prev[0],
                     a * disagree + (1 - a) * prev[1]))
            self._ewma[bucket] = ewma
        m.gauge(f"shadow_dev_ewma_b{bucket}", ewma[0])
        m.gauge(f"shadow_argmax_ewma_b{bucket}", ewma[1])
        thr = self._shadow_thr.get(level)
        if thr is not None and (not np.isfinite(dev) or dev > thr):
            m.incr("shadow_disagreements")
            with self._pending_lock:
                self._pending.append((bucket, level))

    def _apply_pending(self) -> None:
        """Serve-thread application of shadow-worker trips."""
        with self._pending_lock:
            trips, self._pending = self._pending, []
        for bucket, level in trips:
            st = self._engine._bucket_state(bucket)
            if st.quarantined and st.q_level == level:
                continue        # already quarantined on this rung
            self._engine._quarantine(bucket, level)

    def drain(self) -> None:
        """Block until every queued shadow job has run, then apply any
        trips they recorded (tests + orderly shutdown)."""
        if self._queue is not None:
            self._queue.join()
        self._apply_pending()

    def close(self) -> None:
        if self._worker is not None:
            self._queue.put(None)
            self._worker.join(timeout=5.0)
            self._worker = None
            self._queue = None
