"""Architecture registry: ``get_arch("<id>")`` -> ArchSpec.

Every assigned architecture (plus the paper's own JEDI-net models) registers
here; the launcher, dry-run sweep, smoke tests and benchmarks all resolve
archs through this module (``--arch <id>``).
"""

from __future__ import annotations

import importlib

from repro.configs.base import ArchSpec

ARCH_MODULES = {
    # LM family
    "arctic-480b": "repro.configs.arctic_480b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    # GNN family
    "gcn-cora": "repro.configs.gcn_cora",
    "pna": "repro.configs.pna",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "equiformer-v2": "repro.configs.equiformer_v2",
    # RecSys
    "fm": "repro.configs.fm",
    # the paper's own models
    "jedinet-30p": "repro.configs.jedi_30p",
    "jedinet-50p": "repro.configs.jedi_50p",
    "jedinet-tracks-128": "repro.configs.jedi_tracks_128",
}

ASSIGNED_ARCHS = [a for a in ARCH_MODULES if not a.startswith("jedinet")]
ALL_ARCHS = list(ARCH_MODULES)


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(ARCH_MODULES[arch_id])
    return mod.ARCH


def iter_cells(archs=None, include_skipped: bool = False):
    """Yield (arch_spec, shape_spec) for every dry-run cell."""
    for arch_id in (archs or ASSIGNED_ARCHS):
        spec = get_arch(arch_id)
        shapes = spec.shapes if include_skipped else spec.runnable_shapes()
        for shape in shapes.values():
            yield spec, shape
