"""equiformer-v2 [gnn] — SO(2)-eSCN equivariant graph attention.
[arXiv:2306.12059; unverified]

n_layers=12 d_hidden=128 l_max=6 m_max=2 n_heads=8.  Node features are
real-SH irreps (N, (l_max+1)^2 = 49, 128); the eSCN trick reduces the
SO(3) tensor product to per-|m| SO(2) mixes (O(L^3) instead of O(L^6)).
"""

from repro.configs.base import ArchSpec, GNN_SHAPES, GNNConfig

MODEL = GNNConfig(
    name="equiformer-v2",
    kind="equiformer_v2",
    n_layers=12,
    d_hidden=128,
    n_classes=1,                 # energy regression head (invariant)
    l_max=6,
    m_max=2,
    n_heads=8,
    activation="silu",
)

ARCH = ArchSpec(
    arch_id="equiformer-v2",
    family="gnn",
    model=MODEL,
    shapes=dict(GNN_SHAPES),
    source="arXiv:2306.12059; unverified",
    notes="eSCN: rotate to edge frame (Wigner J-matrix fast path), SO(2) "
          "mix per |m| <= 2, rotate back; edge-chunked scan for the "
          "61.8M-edge ogb_products cell.",
)
