"""meshgraphnet [gnn] — encode-process-decode mesh simulator.
[arXiv:2010.03409; unverified]

n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2, edge features from
relative positions.  Output is a per-node regression (3-d velocity update),
so n_classes here is the regression dim.
"""

from repro.configs.base import ArchSpec, GNN_SHAPES, GNNConfig

MODEL = GNNConfig(
    name="meshgraphnet",
    kind="meshgraphnet",
    n_layers=15,
    d_hidden=128,
    n_classes=3,                 # velocity regression
    aggregators=("sum",),
    mlp_layers=2,
    activation="relu",
)

ARCH = ArchSpec(
    arch_id="meshgraphnet",
    family="gnn",
    model=MODEL,
    shapes=dict(GNN_SHAPES),
    source="arXiv:2010.03409; unverified",
    notes="15 message-passing blocks, residual + LayerNorm, 2-layer MLPs.",
)
