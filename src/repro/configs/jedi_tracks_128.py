"""jedinet-tracks-128 — large-graph regime: 128 tracks per event.

The paper's JEDI-net tops out at N_o=50; real-time graph building on
FPGAs (Neu et al., arXiv:2307.07289) and JEDI-linear (Que et al.,
arXiv:2508.15468) target O(100) tracks per event.  At N_o=128 with
f_R width 128 the UNTILED whole-network kernel's (N_o, N_o, H1) grid
needs > 8 MiB of VMEM for a SINGLE sample — the working-set model
rejects it outright (`autotune.fits_vmem`) — so this config is only
servable through the sender-tiled kernel, which holds one
(N_o, block_s, H1) slab plus the Ebar accumulator instead.
16,256 edges per event.
"""

from repro.configs.base import ArchSpec, JEDI_SHAPES
from repro.core.interaction_net import JediNetConfig

MODEL = JediNetConfig(
    n_objects=128,
    n_features=16,
    d_e=8,
    d_o=24,
    n_targets=5,
    fr_hidden=(128, 128),
    fo_hidden=(64, 64),
    phi_hidden=(32, 32),
)

ARCH = ArchSpec(
    arch_id="jedinet-tracks-128",
    family="jedi",
    model=MODEL,
    shapes=dict(JEDI_SHAPES),
    source="arXiv:2307.07289 (track-graph regime) + this repo",
    notes="Large-graph variant: 16,256 edges; untiled full kernel "
          "exceeds the VMEM budget at block_b=1 — sender tiling only.",
)
