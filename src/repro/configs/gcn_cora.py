"""gcn-cora [gnn] — 2-layer GCN (Kipf & Welling). [arXiv:1609.02907; paper]

n_layers=2 d_hidden=16 aggregator=mean norm=sym.  The canonical citation
config: 1433-d bag-of-words features, 7 classes on the full_graph_sm
(cora-sized) cell; the same model scales to ogb_products and the sampled
minibatch_lg cell through the shared segment-op substrate.
"""

from repro.configs.base import ArchSpec, GNN_SHAPES, GNNConfig

MODEL = GNNConfig(
    name="gcn-cora",
    kind="gcn",
    n_layers=2,
    d_hidden=16,
    n_classes=7,
    aggregators=("mean",),
    norm="sym",
    activation="relu",
)

ARCH = ArchSpec(
    arch_id="gcn-cora",
    family="gnn",
    model=MODEL,
    shapes=dict(GNN_SHAPES),
    source="arXiv:1609.02907; paper",
    notes="Sym-normalized SpMM via segment ops; project-then-aggregate.",
)
