"""jedinet-50p — the paper's own model (JEDI-net, 50-particle dataset).

N_o=50, P=16, 3-layer MLPs of width 50 (the U1/U2/U3 baseline from
Table 2); 2450 edges.
"""

from repro.configs.base import ArchSpec, JEDI_SHAPES
from repro.core.interaction_net import JediNetConfig

MODEL = JediNetConfig(
    n_objects=50,
    n_features=16,
    d_e=8,
    d_o=24,
    n_targets=5,
    fr_hidden=(50, 50, 50),
    fo_hidden=(50, 50, 50),
    phi_hidden=(50, 50, 50),
)

ARCH = ArchSpec(
    arch_id="jedinet-50p",
    family="jedi",
    model=MODEL,
    shapes=dict(JEDI_SHAPES),
    source="arXiv:1908.05318 + this paper Table 2",
    notes="Large variant: 2450 edges; the U4/U5 co-designed configs "
          "come from the DSE.",
)
