"""phi3-medium-14b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
"""

from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig

MODEL = TransformerConfig(
    name="phi3-medium-14b",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    rope_theta=10000.0,
    activation="silu",
    remat="layer",
    param_dtype="float32",
    compute_dtype="bfloat16",
)

ARCH = ArchSpec(
    arch_id="phi3-medium-14b",
    family="lm",
    model=MODEL,
    shapes=dict(LM_SHAPES),
    source="arXiv:2404.14219; unverified",
    notes="Dense 14B; largest dense FFN of the assigned set.",
    skipped_shapes={
        "long_500k": "pure full-attention arch: 512k decode requires "
                     "sub-quadratic attention (see DESIGN.md §Skips)",
    },
)
