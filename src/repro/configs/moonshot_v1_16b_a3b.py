"""moonshot-v1-16b-a3b [moe] — Moonlight-16B-A3B (kimi).

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]

Simplification noted in DESIGN.md: Moonlight's dense first layer and shared
expert are folded into the uniform 64e top-6 stack (scan-stacked layers must
be homogeneous; parameter count deviation < 2%).
"""

from repro.configs.base import (
    ArchSpec, LM_SHAPES, MoEConfig, TransformerConfig,
)

MODEL = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(n_experts=64, top_k=6),
    rope_theta=50000.0,
    activation="silu",
    remat="layer",
    param_dtype="float32",
    compute_dtype="bfloat16",
)

ARCH = ArchSpec(
    arch_id="moonshot-v1-16b-a3b",
    family="lm",
    model=MODEL,
    shapes=dict(LM_SHAPES),
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
    notes="64-expert top-6 MoE, ~3B active.",
    skipped_shapes={
        "long_500k": "pure full-attention arch: 512k decode requires "
                     "sub-quadratic attention (see DESIGN.md §Skips)",
    },
)
