"""pna [gnn] — Principal Neighbourhood Aggregation. [arXiv:2004.05718; paper]

n_layers=4 d_hidden=75, aggregators mean/max/min/std, scalers
identity/amplification/attenuation (12 aggregated views per layer).
"""

from repro.configs.base import ArchSpec, GNN_SHAPES, GNNConfig

MODEL = GNNConfig(
    name="pna",
    kind="pna",
    n_layers=4,
    d_hidden=75,
    n_classes=16,
    aggregators=("mean", "max", "min", "std"),
    scalers=("identity", "amplification", "attenuation"),
    activation="relu",
)

ARCH = ArchSpec(
    arch_id="pna",
    family="gnn",
    model=MODEL,
    shapes=dict(GNN_SHAPES),
    source="arXiv:2004.05718; paper",
    notes="4 aggregators x 3 degree scalers -> 12x concat per layer.",
)
