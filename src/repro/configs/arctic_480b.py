"""arctic-480b [moe] — Snowflake Arctic base (dense-MoE hybrid).

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 with a
parallel dense residual FFN.  [hf:Snowflake/snowflake-arctic-base; hf]

~479B total / ~17B active params.  Training memory plan for 256 x 16GiB
chips: bf16 params fully sharded over (data x model) = ~3.7 GiB/chip, bf16
grads ~3.7 GiB, Adafactor (factored second moment) states ~MBs — AdamW's
fp32 m/v (3.8 TiB global) cannot fit this pod, which is exactly the
distributed-optimization trade the config encodes.
"""

from repro.configs.base import (
    ArchSpec, LM_SHAPES, MoEConfig, TransformerConfig,
)

MODEL = TransformerConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(n_experts=128, top_k=2, dense_residual=True),
    rope_theta=10000.0,
    activation="silu",
    remat="layer",
    param_dtype="bfloat16",     # see memory plan above
    compute_dtype="bfloat16",
)

ARCH = ArchSpec(
    arch_id="arctic-480b",
    family="lm",
    model=MODEL,
    shapes=dict(LM_SHAPES),
    source="hf:Snowflake/snowflake-arctic-base; hf",
    notes="128-expert top-2 MoE + dense residual branch per layer.",
    skipped_shapes={
        "long_500k": "pure full-attention arch: 512k decode requires "
                     "sub-quadratic attention (see DESIGN.md §Skips)",
    },
)
