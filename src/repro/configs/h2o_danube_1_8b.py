"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA.
[arXiv:2401.16818; hf]

The SWA rolling KV cache makes this the one assigned LM arch that runs the
long_500k cell: decode at 512k context touches only the 4096-token window.
"""

from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig

MODEL = TransformerConfig(
    name="h2o-danube-1.8b",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10000.0,
    activation="silu",
    remat="layer",
    param_dtype="float32",
    compute_dtype="bfloat16",
)

ARCH = ArchSpec(
    arch_id="h2o-danube-1.8b",
    family="lm",
    model=MODEL,
    shapes=dict(LM_SHAPES),
    source="arXiv:2401.16818; hf",
    notes="Sliding-window attention (W=4096) -> sub-quadratic long decode.",
)
