"""fm [recsys] — Factorization Machine. [ICDM'10 (Rendle); paper]

n_sparse=39 embed_dim=10, pairwise interactions via the O(nk) sum-square
strength reduction.  Table sizes follow a Criteo-like skewed distribution:
a few 10M+-row id fields, a long tail of small ones — ~86M rows total
(~3.4 GiB fp32), row-sharded over the full chip set.
"""

import numpy as np

from repro.configs.base import ArchSpec, RECSYS_SHAPES, RecsysConfig


def _criteo_like_sizes(n_fields: int = 39, seed: int = 7) -> tuple:
    """Deterministic power-law table sizes: max 40M rows, min 4 rows."""
    rng = np.random.RandomState(seed)
    # log-uniform between 10^0.6 and 10^7.6, with the 4 largest pinned so
    # the total is stable across numpy versions.
    sizes = np.power(10.0, rng.uniform(0.6, 6.3, size=n_fields)).astype(np.int64)
    sizes[:4] = (40_000_000, 25_000_000, 12_000_000, 8_000_000)
    return tuple(int(s) for s in sizes)


MODEL = RecsysConfig(
    name="fm",
    kind="fm",
    n_sparse=39,
    embed_dim=10,
    vocab_sizes=_criteo_like_sizes(),
)

ARCH = ArchSpec(
    arch_id="fm",
    family="recsys",
    model=MODEL,
    shapes=dict(RECSYS_SHAPES),
    source="ICDM'10 (Rendle); paper",
    notes=f"{MODEL.total_rows:,} total embedding rows; single concatenated "
          "row-sharded table (TBE layout), one gather per batch.",
)
