"""minicpm-2b [dense] — llama-like arch trained with the WSD schedule.

40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753.
[arXiv:2404.06395; hf]

The WSD (warmup-stable-decay) LR schedule the paper introduces is
implemented in repro/training/schedule.py and wired to this arch's trainer
defaults.  vocab 122753 pads to 122880 for 256-way sharding.
"""

from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig

MODEL = TransformerConfig(
    name="minicpm-2b",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,        # MiniCPM ties embeddings
    rope_theta=10000.0,
    activation="silu",
    remat="layer",
    param_dtype="float32",
    compute_dtype="bfloat16",
)

ARCH = ArchSpec(
    arch_id="minicpm-2b",
    family="lm",
    model=MODEL,
    shapes=dict(LM_SHAPES),
    source="arXiv:2404.06395; hf",
    notes="MHA (kv=36); WSD schedule is the training-side feature.",
    skipped_shapes={
        "long_500k": "pure full-attention arch: 512k decode requires "
                     "sub-quadratic attention (see DESIGN.md §Skips)",
    },
)
