"""Config schema: architectures x input shapes x run settings.

Every assigned architecture gets one module in ``repro/configs/`` exporting
an ``ARCH`` (family-specific config dataclass wrapped in ``ArchSpec``).
Shapes are family-wide (LM / GNN / RecSys) with per-arch overrides; each
(arch x shape) cell defines the step function to lower (train_step vs
serve_step) and its abstract input specs for the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

# --- model-family configs ---------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Arctic-style dense residual FFN running in parallel with the experts.
    dense_residual: bool = False
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None         # default d_model // n_heads
    moe: Optional[MoEConfig] = None
    sliding_window: Optional[int] = None   # SWA (h2o-danube)
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"
    activation: str = "silu"               # SwiGLU by default
    tie_embeddings: bool = False
    # remat policy for train_step: "none" | "layer" (checkpoint each block)
    remat: str = "layer"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # Unroll every lax.scan (layers / kv chunks / CE chunks).  Production
    # keeps scans for compile time; the dry-run's COST variant unrolls so
    # HLO cost_analysis counts every trip (a while body is counted ONCE,
    # undercounting a 40-layer scan 40x).  See launch/dryrun.py.
    unroll_scans: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_full_attention(self) -> bool:
        return self.sliding_window is None


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                    # gcn | pna | meshgraphnet | equiformer_v2 | jedinet
    n_layers: int
    d_hidden: int
    n_classes: int = 16
    aggregators: tuple = ("mean",)
    scalers: tuple = ("identity",)
    mlp_layers: int = 2          # meshgraphnet per-MLP depth
    l_max: int = 0               # equiformer
    m_max: int = 0
    n_heads: int = 1
    norm: str = "layernorm"
    activation: str = "relu"
    param_dtype: str = "float32"
    compute_dtype: str = "float32"   # GNNs here are small; fp32 keeps eSCN stable
    remat: str = "none"
    unroll_scans: bool = False       # see TransformerConfig.unroll_scans
    edge_chunk: int = 1 << 20        # equiformer eSCN conv edge-scan chunk


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str = "fm"
    n_sparse: int = 39
    embed_dim: int = 10
    # Criteo-like skewed table sizes; the total is what matters for sharding.
    vocab_sizes: tuple = ()
    dense_dim: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        return int(sum(self.vocab_sizes))


# --- shapes ------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode | full_graph | minibatch |
    #                  batched_graphs | recsys_train | recsys_serve | retrieval
    dims: dict

    def dim(self, k: str, default=None):
        return self.dims.get(k, default)


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          dict(seq_len=4096, global_batch=256)),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             dict(seq_len=32768, global_batch=32)),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            dict(seq_len=32768, global_batch=128)),
    "long_500k": ShapeSpec("long_500k", "decode",
                           dict(seq_len=524288, global_batch=1)),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "full_graph",
                               dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    "minibatch_lg": ShapeSpec("minibatch_lg", "minibatch",
                              dict(n_nodes=232965, n_edges=114615892,
                                   batch_nodes=1024, fanout=(15, 10),
                                   d_feat=602)),
    "ogb_products": ShapeSpec("ogb_products", "full_graph",
                              dict(n_nodes=2449029, n_edges=61859140,
                                   d_feat=100)),
    "molecule": ShapeSpec("molecule", "batched_graphs",
                          dict(n_nodes=30, n_edges=64, batch=128, d_feat=16)),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "recsys_train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "recsys_serve", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "recsys_serve", dict(batch=262144)),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1000000)),
}

JEDI_SHAPES = {
    "stream_1k": ShapeSpec("stream_1k", "jedi_infer", dict(batch=1000)),
    "train_jets": ShapeSpec("train_jets", "jedi_train", dict(batch=4096)),
}


# --- arch wrapper -------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                  # lm | gnn | recsys | jedi
    model: Any                   # TransformerConfig | GNNConfig | RecsysConfig | JediNetConfig
    shapes: dict                 # name -> ShapeSpec
    source: str = ""             # citation tag from the assignment
    notes: str = ""
    # cells intentionally not run for this arch (e.g. long_500k on pure
    # full-attention archs), mapped to the reason string for DESIGN.md.
    skipped_shapes: dict = dataclasses.field(default_factory=dict)

    def runnable_shapes(self):
        return {k: v for k, v in self.shapes.items()
                if k not in self.skipped_shapes}
