"""jedinet-30p — the paper's own model (JEDI-net, 30-particle dataset).

JEDI-net [5] (arXiv:1908.05318) as accelerated by LL-GNN: N_o=30, P=16,
3-layer MLPs of width 20 (the J1/J2 baseline size from Table 2).  The
co-design search (repro/core/codesign.py) re-balances these sizes into the
J3..J5 variants.
"""

from repro.configs.base import ArchSpec, JEDI_SHAPES
from repro.core.interaction_net import JediNetConfig

MODEL = JediNetConfig(
    n_objects=30,
    n_features=16,
    d_e=8,
    d_o=24,
    n_targets=5,
    fr_hidden=(20, 20, 20),
    fo_hidden=(20, 20, 20),
    phi_hidden=(20, 20, 20),
)

ARCH = ArchSpec(
    arch_id="jedinet-30p",
    family="jedi",
    model=MODEL,
    shapes=dict(JEDI_SHAPES),
    source="arXiv:1908.05318 + this paper Table 2",
    notes="The paper's end-to-end application; 870 edges.",
)
