from repro.nn.core import (
    ACTIVATIONS,
    dense_init,
    dense_apply,
    mlp_init,
    mlp_apply,
    mlp_dims,
    rmsnorm_init,
    rmsnorm_apply,
    layernorm_init,
    layernorm_apply,
)

__all__ = [
    "ACTIVATIONS",
    "dense_init",
    "dense_apply",
    "mlp_init",
    "mlp_apply",
    "mlp_dims",
    "rmsnorm_init",
    "rmsnorm_apply",
    "layernorm_init",
    "layernorm_apply",
]
