"""Attention: GQA grouped-head attention with causal / sliding-window masks.

Two execution paths, numerically identical:

* full     — one einsum, softmax over the whole KV axis.  Used for decode
  (q_len == 1) and short sequences.
* blockwise — lax.scan over KV chunks with an online-softmax carry
  (running max / denominator / accumulator), optionally also mapping over
  query chunks.  This is FlashAttention's tiling expressed at the XLA level:
  the (Sq x Skv) score matrix never materializes, which is what makes the
  prefill_32k and train_4k cells fit HBM.  (A Pallas flash-decode kernel for
  the KV-cache-bound serving path lives in repro/kernels/flash_decode.)

Masking is positional: callers pass integer positions for q and kv; invalid
KV slots (unwritten cache entries) carry position -1 and are masked out.
Sliding-window attention (h2o-danube) adds `q_pos - kv_pos < window`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask_bias(q_pos, kv_pos, *, causal: bool, window):
    """(B, Sq, Skv) additive bias from positional masking rules."""
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = kv_pos[..., None, :].astype(jnp.int32)
    ok = kp >= 0                                   # valid cache slot
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= (qp - kp) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _scores(q, k, scale):
    # q: (B, Sq, Hkv, G, D)  k: (B, Skv, Hkv, D) -> (B, Hkv, G, Sq, Skv)
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32) * scale


def _attend_full(q, k, v, bias):
    s = _scores(q, k, 1.0)                          # scale pre-applied to q
    s = s + bias[:, None, None, :, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    l = jnp.moveaxis(l, (1, 2, 3), (2, 3, 1))       # (B, Sq, Hkv, G, 1)
    return (o / jnp.maximum(l, 1e-30)).astype(v.dtype)


def _attend_blockwise(q, k, v, bias, kv_chunk: int, unroll: bool = False):
    b, sq, hkv, g, d = q.shape
    skv = k.shape[1]
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, 0), (0, pad)),
                       constant_values=NEG_INF)
    k = k.reshape(b, n_chunks, kv_chunk, hkv, d)
    v = v.reshape(b, n_chunks, kv_chunk, hkv, d)
    bias = bias.reshape(b, sq, n_chunks, kv_chunk)

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, bc = xs                              # (B,C,Hkv,D), (B,Sq,C)
        s = _scores(q, kc, 1.0) + bc[:, None, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    # scan over the chunk axis (moved to front)
    xs = (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
          jnp.moveaxis(bias, 2, 0))
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), xs,
                                  unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, (1, 2), (2, 3)).astype(v.dtype)  # (B,Sq,Hkv,G,D)


def attention(q, k, v, *, q_pos, kv_pos, causal: bool = True,
              window=None, kv_chunk=None, q_chunk=None,
              unroll: bool = False):
    """Grouped-query attention.

    q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D); H % Hkv == 0.
    q_pos: (B, Sq) int32; kv_pos: (B, Skv) int32, -1 for invalid slots.
    Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    scale = 1.0 / (d ** 0.5)
    qg = (q * scale).reshape(b, sq, hkv, g, d)

    def run(qg_, qpos_):
        bias = _mask_bias(qpos_, kv_pos, causal=causal, window=window)
        if kv_chunk is not None and k.shape[1] > kv_chunk:
            o = _attend_blockwise(qg_, k, v, bias, kv_chunk, unroll=unroll)
        else:
            o = _attend_full(qg_, k, v, bias)
        return o

    if q_chunk is not None and sq > q_chunk and sq % q_chunk == 0:
        nq = sq // q_chunk
        qg_c = jnp.moveaxis(qg.reshape(b, nq, q_chunk, hkv, g, d), 1, 0)
        qp_c = jnp.moveaxis(q_pos.reshape(b, nq, q_chunk), 1, 0)
        _, o = jax.lax.scan(lambda _c, xs: (None, run(*xs)), None,
                            (qg_c, qp_c), unroll=nq if unroll else 1)
        o = jnp.moveaxis(o, 0, 1).reshape(b, sq, hkv, g, d)
    else:
        o = run(qg, q_pos)
    return o.reshape(b, sq, h, d)
