"""Minimal NN substrate: linear / MLP / norms as pure functions over dict pytrees.

Conventions
-----------
* Parameters are stored in fp32 (`param_dtype`) and cast to `compute_dtype`
  (usually bf16 on TPU) at use — the standard mixed-precision recipe.
* A Linear is ``{"w": (in, out), "b": (out,)}``; activations act on the last
  axis.  Everything is shape-polymorphic on leading batch axes.
* Matmul always contracts the LAST axis of the input with the FIRST axis of
  the weight — i.e. features live contiguously in the minor-most dimension.
  This is the TPU analogue of the paper's "column-major order" (Sec. 3.2):
  the per-node / per-edge feature vectors that the JEDI-net MLPs consume are
  contiguous, so the MXU sees one large (rows x features) GEMM instead of a
  strided gather.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


def _selu(x):
    return jax.nn.selu(x)


ACTIVATIONS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "selu": _selu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "identity": lambda x: x,
}


def dense_init(key, in_dim: int, out_dim: int, *, dtype=jnp.float32, scale: str = "fan_in",
               use_bias: bool = True):
    """He/LeCun-style variance-scaling init."""
    if scale == "fan_in":
        std = math.sqrt(2.0 / in_dim)
    elif scale == "lecun":
        std = math.sqrt(1.0 / in_dim)
    elif scale == "fan_avg":
        std = math.sqrt(2.0 / (in_dim + out_dim))
    else:
        raise ValueError(f"unknown init scale {scale}")
    w = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * std
    p = {"w": w.astype(dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype=dtype)
    return p


def dense_apply(p, x, *, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        b = p["b"].astype(y.dtype)
        y = y + b
    return y


def mlp_dims(in_dim: int, hidden: Sequence[int], out_dim: int) -> list:
    """Layer (in, out) dims for an MLP with the given hidden sizes."""
    dims = [in_dim, *hidden, out_dim]
    return list(zip(dims[:-1], dims[1:]))


def mlp_init(key, in_dim: int, hidden: Sequence[int], out_dim: int, *,
             dtype=jnp.float32, scale: str = "fan_in"):
    layers = []
    dims = mlp_dims(in_dim, hidden, out_dim)
    keys = jax.random.split(key, len(dims))
    for k, (din, dout) in zip(keys, dims):
        layers.append(dense_init(k, din, dout, dtype=dtype, scale=scale))
    return {"layers": layers}


def mlp_apply(p, x, *, activation: str = "relu", final_activation: str = "identity",
              compute_dtype=None):
    """Apply an MLP: activation between layers, `final_activation` at the end."""
    act = ACTIVATIONS[activation]
    fact = ACTIVATIONS[final_activation]
    layers = p["layers"]
    for i, lp in enumerate(layers):
        x = dense_apply(lp, x, compute_dtype=compute_dtype)
        x = act(x) if i < len(layers) - 1 else fact(x)
    return x


def rmsnorm_init(dim: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm_apply(p, x, *, eps: float = 1e-6):
    orig_dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(orig_dtype)


def layernorm_init(dim: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype), "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm_apply(p, x, *, eps: float = 1e-5):
    orig_dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(orig_dtype)
