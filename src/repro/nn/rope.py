"""Rotary position embeddings (llama rotate-half convention)."""

from __future__ import annotations

import jax.numpy as jnp


def rope_cos_sin(positions, head_dim: int, theta: float = 10000.0,
                 dtype=jnp.float32):
    """cos/sin tables for given integer positions.

    positions: (..., S) int32 -> cos/sin: (..., S, head_dim//2)
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (..., S, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xc = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out1 = x1f * c - x2f * s
    out2 = x2f * c + x1f * s
    return jnp.concatenate([out1, out2], axis=-1).astype(xc)
