"""Shared VMEM batch-tile autotuning helpers for batch-gridded kernels.

Model-agnostic pieces used by every kernel package that grids over the
batch axis only (fused_jedinet, fm_interaction): pick a batch tile from
a per-sample VMEM working set, and pad non-divisible batches to the
tile instead of degrading the tile.  Per-kernel working-set estimators
stay with their kernels (e.g. fused_jedinet/autotune.py).
"""

from __future__ import annotations

import jax.numpy as jnp

# Half of the ~16 MB/core VMEM: the other half covers Mosaic's
# input/output double buffering and the broadcast weight blocks.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

# fp32 sublane count — tiles that are a multiple of this pack the
# (8, 128) native tile exactly when the batch axis lands on a sublane.
_SUBLANE = 8


def effective_budget(budget_bytes: int, reserved_bytes: int) -> int:
    """Budget left for batch rows after ``reserved_bytes`` of VMEM
    residency (a path's weight blocks, :func:`weight_vmem_bytes`) is
    spoken for, floored at 1/8 of the budget so a pathologically heavy
    reservation degrades the tile instead of zeroing it.  THE one
    definition of the reservation policy — the serving ladder
    (:func:`bucket_ladder`) and the kernel-side 2D tile picker
    (``fused_jedinet.autotune.pick_block_b_s``) must stay in lockstep,
    or the engine pads to buckets the kernel tiles differently for."""
    return max(budget_bytes - max(int(reserved_bytes), 0), budget_bytes // 8)


def mlp_widths(params) -> list[int]:
    """Output widths of each layer of a ``{"layers": [{"w", "b"}, ...]}`` MLP."""
    return [int(lp["w"].shape[-1]) for lp in params["layers"]]


def weight_vmem_bytes(params, compute_dtype=None) -> int:
    """VMEM residency of a params pytree at the dtypes the kernels SHIP:
    integer (quantized) weights verbatim — 1 B/element where their fp32
    twins bill 4, which is how quantized paths reserve less of the
    budget and earn deeper bucket ladders (see :func:`bucket_ladder`'s
    ``reserved_bytes``) — fp weights at ``compute_dtype`` (the wrappers
    cast them down before the kernel; ``None`` bills the stored dtype),
    and biases/scales at their stored fp32."""
    import jax
    cbytes = None if compute_dtype is None \
        else jnp.dtype(compute_dtype).itemsize

    def leaf_bytes(path, x):
        item = jnp.dtype(x.dtype).itemsize
        is_w = any(getattr(k, "key", None) == "w" for k in path)
        if is_w and cbytes is not None \
                and not jnp.issubdtype(x.dtype, jnp.integer):
            item = cbytes
        return x.size * item

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return int(sum(leaf_bytes(path, x) for path, x in flat))


def pick_block_b(batch: int, per_sample_bytes: int,
                 budget_bytes: int = VMEM_BUDGET_BYTES) -> int:
    """Largest useful batch tile whose working set fits the VMEM budget.

    Never constrained to divide ``batch`` — pad with :func:`pad_batch`
    instead.  Three cases:

    * whole batch fits the budget -> one grid step, zero padding;
    * otherwise take the budget-limited grid-step count and BALANCE the
      tile to it (``ceil(batch / steps)``), which minimizes padded rows
      for that step count (e.g. B=256 at budget-tile 96: 3 steps of 88
      pads 8 rows, vs 3 steps of 96 padding 32);
    * sublane-align the balanced tile when that still fits the budget.
    """
    bb = max(1, min(batch, budget_bytes // max(per_sample_bytes, 1)))
    if bb >= batch:
        return batch
    steps = -(-batch // bb)
    bb = -(-batch // steps)
    if bb > _SUBLANE:
        aligned = -(-bb // _SUBLANE) * _SUBLANE
        if aligned * per_sample_bytes <= budget_bytes:
            bb = aligned
    return bb


def bucket_ladder(max_batch: int, per_sample_bytes: int,
                  budget_bytes: int = VMEM_BUDGET_BYTES, *,
                  reserved_bytes: int = 0) -> list[int]:
    """Serving pad-to-bucket batch sizes derived from the VMEM tile.

    Requests are padded UP to the nearest bucket so every bucket compiles
    exactly once (a warm cache) and an arbitrary request count never
    triggers a fresh trace.  The ladder is shaped so padding can never
    force a tile-degenerate kernel either:

    * below the VMEM-optimal tile: sublane-aligned doublings (8, 16, 32,
      ...) — each fits the budget whole, so the kernel runs one grid step
      with ``block_b == bucket``;
    * at and above the tile: whole-tile doublings (t, 2t, 4t, ...) — each
      bucket is an exact tile multiple, so the grid tiles it with zero
      intra-kernel padding.

    The last bucket always covers ``max_batch`` (larger requests are
    chunked by the caller).

    ``reserved_bytes`` is VMEM spoken for before any batch row arrives —
    the path's weight blocks (:func:`weight_vmem_bytes`).  It shrinks
    the effective budget, so a path whose weights are int8 (1 B/element
    resident) keeps a larger tile — and therefore a deeper ladder — than
    the same network in fp32: the quantization-aware per-path bucket
    policy (``PathSpec.bucket_ladder`` threads it through).
    """
    max_batch = max(int(max_batch), 1)
    budget_bytes = effective_budget(budget_bytes, reserved_bytes)
    tile = pick_block_b(max_batch, per_sample_bytes, budget_bytes)
    ladder: list[int] = []
    b = _SUBLANE
    while b < min(tile, max_batch):
        ladder.append(b)
        b *= 2
    t = tile
    while t < max_batch:
        ladder.append(t)
        t *= 2
    ladder.append(min(t, padded_batch(max_batch, tile)))
    return sorted(set(ladder))


def bucket_for(bucket_sizes, n_events: int) -> int:
    """Smallest bucket holding ``n_events`` (largest if none do — callers
    chunk oversized requests through it).  ``bucket_sizes`` ascending."""
    for b in bucket_sizes:
        if n_events <= b:
            return b
    return bucket_sizes[-1]


def padded_batch(batch: int, block_b: int) -> int:
    """``batch`` rounded up to the next multiple of ``block_b``."""
    return ((batch + block_b - 1) // block_b) * block_b


def pad_batch(x, block_b: int):
    """Zero-pad axis 0 of ``x`` up to the next ``block_b`` multiple.

    Returns the (possibly aliased) padded array; callers slice kernel
    output back to ``x.shape[0]`` rows.
    """
    pad = padded_batch(x.shape[0], block_b) - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths)
