"""Jit'd public wrappers for the fused JEDI-net kernels.

Two entry points:

* :func:`fused_edge_block` — edge-only fusion (B-construct + f_R + MMM3 in
  VMEM); Ebar returns to XLA for f_O / phi_O.
* :func:`fused_forward_full` — whole-network fusion (x -> logits in one
  kernel); the only HBM traffic is weights + x in, logits out.  The
  sender axis is tiled (``block_s``) with an fp32 VMEM accumulator, so
  the batch tile is chosen from the TILED live set — much larger than
  the untiled kernel allowed — and graphs past N_o ~ 100 fit at all.
  int8-quantized params (layers carrying ``"w_scale"``, see
  ``core/int8_path.py``) are detected here and served with IN-KERNEL
  dequantization: the kernel reads 1-byte weights from HBM and folds
  the scales into the fp32 accumulator.

Both pick their batch tile from the working-set autotuner (autotune.py)
and PAD non-divisible batches to the next tile multiple instead of
degrading the tile size — a prime batch (B=1009) keeps its VMEM-optimal
tile and pays <1% padded compute rather than running a 1009-step grid.

The MXU compute dtype is ``cfg.compute_dtype`` (the paper's precision /
latency co-design knob): weights and x are cast down, accumulation and
the two reductions stay fp32.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_jedinet import autotune
from repro.kernels.fused_jedinet import full_kernel as FK
from repro.kernels.fused_jedinet import kernel as K


def is_quantized_params(params) -> bool:
    """True when the MLP layers carry int8 weights + dequant scales.

    Quantization is all-or-nothing (``quantize_params_int8`` quantizes
    every layer): a mixed pytree would send some fp32 weights through
    the int8 scale plumbing, so it is rejected here at the boundary
    instead of failing opaquely inside the kernel.
    """
    flags = [("w_scale" in lp)
             for mlp in params.values() for lp in mlp["layers"]]
    if any(flags) and not all(flags):
        raise ValueError(
            "partially quantized params: every MLP layer must carry "
            "'w_scale' (quantize_params_int8 quantizes all layers); "
            "mixed fp32/int8 pytrees are not supported")
    return all(flags) and bool(flags)


@partial(jax.jit, static_argnames=("cfg", "interpret", "block_b"))
def fused_edge_block(params_fr, cfg, x, *, interpret: bool = False,
                     block_b: int | None = None):
    """Ebar = aggregated f_R messages. x: (B, N_o, P) -> (B, N_o, D_e)."""
    if any("w_scale" in lp for lp in params_fr["layers"]):
        # the edge kernel has no dequant-scale plumbing: int8 weights
        # would matmul unscaled (and truncate activations to int8) —
        # reject at the boundary, like fused_forward_full's
        # is_quantized_params guard
        raise ValueError(
            "fused_edge_block does not support int8-quantized params; "
            "serve quantized weights through fused_forward_full "
            "(in-kernel dequant) or dequantize_params first")
    cdt = jnp.dtype(cfg.compute_dtype)
    w1r, w1s, b1, rest = K.split_first_layer(params_fr, cfg.n_features,
                                             dtype=cdt)
    widths = [w1r.shape[-1]] + [r.shape[-1] for r in rest[::2]]
    bb = block_b or autotune.pick_block_b(
        x.shape[0],
        autotune.edge_block_bytes_per_sample(cfg.n_objects, cfg.n_features,
                                             widths))
    bsz = x.shape[0]
    xp = autotune.pad_batch(x.astype(cdt), bb)
    out = K.fused_edge_block_kernel_call(
        xp, w1r, w1s, b1, rest,
        activation=cfg.activation, block_b=bb, interpret=interpret)
    return out[:bsz]


@partial(jax.jit, static_argnames=("cfg", "interpret", "block_b", "block_s"))
def fused_forward_full(params, cfg, x, *, interpret: bool = False,
                       block_b: int | None = None,
                       block_s: int | None = None):
    """Whole-network fused forward. x: (B, N_o, P) -> logits (B, n_targets).

    ``params`` may be raw fp32/bf16 MLPs or int8-quantized ones
    (``quantize_params_int8``); quantized layers keep their int8 weights
    all the way into VMEM.  ``(block_b, block_s)`` default to the 2D
    working-set autotuner; pass either explicitly to pin it (tests).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    quantized = is_quantized_params(params)
    fr = K.split_first_layer(params["fr"], cfg.n_features, dtype=cdt)
    fr_arrays = [fr[0], fr[1], fr[2], *fr[3]]
    fo_arrays = FK.flatten_mlp(params["fo"], cdt)
    phi_arrays = FK.flatten_mlp(params["phi"], cdt)
    scales = None
    if quantized:
        s_fr = FK.mlp_scales(params["fr"])
        # w1 splits into (w1r, w1s): both halves share w1's tensor scale
        scales = [s_fr[0], s_fr[0], *s_fr[1:],
                  *FK.mlp_scales(params["fo"]), *FK.mlp_scales(params["phi"])]

    if block_b is None or block_s is None:
        fr_w = autotune.mlp_widths(params["fr"])
        fo_w = autotune.mlp_widths(params["fo"])
        phi_w = autotune.mlp_widths(params["phi"])
        reserved = autotune.weight_vmem_bytes(params, cfg.compute_dtype)
        if block_b is None and block_s is None:
            block_b, block_s = autotune.pick_block_b_s(
                x.shape[0], cfg.n_objects, cfg.n_features,
                fr_w, fo_w, phi_w, reserved_bytes=reserved)
        elif block_b is None:
            # block_s pinned: tune the batch tile UNDER it — reusing the
            # jointly-tuned block_b of a different sender tile could bust
            # the budget (the pinned pair was never validated together)
            per = autotune.full_forward_tiled_bytes_per_sample(
                cfg.n_objects, cfg.n_features, fr_w, fo_w, phi_w,
                min(int(block_s), cfg.n_objects))
            block_b = autotune.pick_block_b(
                x.shape[0], per,
                autotune.effective_budget(autotune.VMEM_BUDGET_BYTES,
                                          reserved))
        else:
            # block_b pinned: largest sender tile that fits beside it
            block_s = autotune.pick_block_s(
                block_b, cfg.n_objects, cfg.n_features,
                fr_w, fo_w, phi_w, reserved_bytes=reserved)
    bsz = x.shape[0]
    xp = autotune.pad_batch(x.astype(cdt), block_b)
    out = FK.fused_forward_full_kernel_call(
        xp, fr_arrays, fo_arrays, phi_arrays,
        activation=cfg.activation, n_targets=cfg.n_targets,
        block_b=block_b, block_s=block_s, scales=scales, interpret=interpret)
    return out[:bsz]
