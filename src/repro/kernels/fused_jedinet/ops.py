"""Jit'd public wrapper for the fused JEDI-net edge block."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_jedinet import kernel as K


def _pick_block_b(bsz: int, n_o: int, width: int) -> int:
    """Largest batch tile whose activation grid fits a ~8 MB VMEM budget."""
    budget = 8 * 1024 * 1024
    per_sample = n_o * n_o * max(width, 8) * 4          # fp32 grid acts
    bb = max(1, min(bsz, budget // max(per_sample, 1)))
    # round down to a divisor of bsz (grid must tile exactly)
    while bsz % bb:
        bb -= 1
    return bb


@partial(jax.jit, static_argnames=("cfg", "interpret", "block_b"))
def fused_edge_block(params_fr, cfg, x, *, interpret: bool = False,
                     block_b: int | None = None):
    """Ebar = aggregated f_R messages. x: (B, N_o, P) -> (B, N_o, D_e)."""
    w1r, w1s, b1, rest = K.split_first_layer(params_fr, cfg.n_features)
    width = max([w1r.shape[-1]] + [r.shape[-1] for r in rest[::2]])
    bb = block_b or _pick_block_b(x.shape[0], cfg.n_objects, width)
    return K.fused_edge_block_kernel_call(
        x.astype(jnp.float32), w1r, w1s, b1, rest,
        activation=cfg.activation, block_b=bb, interpret=interpret)
