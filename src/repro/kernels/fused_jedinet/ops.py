"""Jit'd public wrappers for the fused JEDI-net kernels.

Two entry points:

* :func:`fused_edge_block` — edge-only fusion (B-construct + f_R + MMM3 in
  VMEM); Ebar returns to XLA for f_O / phi_O.
* :func:`fused_forward_full` — whole-network fusion (x -> logits in one
  kernel); the only HBM traffic is weights + x in, logits out.

Both pick their batch tile from the working-set autotuner (autotune.py)
and PAD non-divisible batches to the next tile multiple instead of
degrading the tile size — a prime batch (B=1009) keeps its VMEM-optimal
tile and pays <1% padded compute rather than running a 1009-step grid.

The MXU compute dtype is ``cfg.compute_dtype`` (the paper's precision /
latency co-design knob): weights and x are cast down, accumulation and
the two reductions stay fp32.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_jedinet import autotune
from repro.kernels.fused_jedinet import full_kernel as FK
from repro.kernels.fused_jedinet import kernel as K


@partial(jax.jit, static_argnames=("cfg", "interpret", "block_b"))
def fused_edge_block(params_fr, cfg, x, *, interpret: bool = False,
                     block_b: int | None = None):
    """Ebar = aggregated f_R messages. x: (B, N_o, P) -> (B, N_o, D_e)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    w1r, w1s, b1, rest = K.split_first_layer(params_fr, cfg.n_features,
                                             dtype=cdt)
    widths = [w1r.shape[-1]] + [r.shape[-1] for r in rest[::2]]
    bb = block_b or autotune.pick_block_b(
        x.shape[0],
        autotune.edge_block_bytes_per_sample(cfg.n_objects, cfg.n_features,
                                             widths))
    bsz = x.shape[0]
    xp = autotune.pad_batch(x.astype(cdt), bb)
    out = K.fused_edge_block_kernel_call(
        xp, w1r, w1s, b1, rest,
        activation=cfg.activation, block_b=bb, interpret=interpret)
    return out[:bsz]


@partial(jax.jit, static_argnames=("cfg", "interpret", "block_b"))
def fused_forward_full(params, cfg, x, *, interpret: bool = False,
                       block_b: int | None = None):
    """Whole-network fused forward. x: (B, N_o, P) -> logits (B, n_targets)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    fr = K.split_first_layer(params["fr"], cfg.n_features, dtype=cdt)
    fr_arrays = [fr[0], fr[1], fr[2], *fr[3]]
    fo_arrays = FK.flatten_mlp(params["fo"], cdt)
    phi_arrays = FK.flatten_mlp(params["phi"], cdt)

    bb = block_b or autotune.pick_block_b(
        x.shape[0],
        autotune.full_forward_bytes_per_sample(
            cfg.n_objects, cfg.n_features,
            autotune.mlp_widths(params["fr"]),
            autotune.mlp_widths(params["fo"]),
            autotune.mlp_widths(params["phi"])))
    bsz = x.shape[0]
    xp = autotune.pad_batch(x.astype(cdt), bb)
    out = FK.fused_forward_full_kernel_call(
        xp, fr_arrays, fo_arrays, phi_arrays,
        activation=cfg.activation, n_targets=cfg.n_targets,
        block_b=bb, interpret=interpret)
    return out[:bsz]
