"""Pure-jnp oracle for the fused JEDI-net edge block.

Computes Ebar = (sum of f_R messages over incoming edges) per node, i.e.
MMM1/2 + f_R + MMM3 of the paper, using the strength-reduced but UNFUSED
path (explicit B matrix in "HBM").  The Pallas kernel must match this to
float tolerance for every shape/dtype in the sweep.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import adjacency
from repro.nn import core as nn


def fused_edge_block_ref(params_fr, cfg, x):
    """x: (B, N_o, P) -> Ebar (B, N_o, D_e), float32."""
    n_o, p = cfg.n_objects, cfg.n_features
    send_idx = jnp.asarray(adjacency.sender_index_matrix(n_o))    # (N_o, N_o-1)

    b1 = jnp.broadcast_to(x[..., :, None, :],
                          (*x.shape[:-2], n_o, n_o - 1, p))
    b2 = jnp.take(x, send_idx.reshape(-1), axis=-2)
    b2 = b2.reshape(*x.shape[:-2], n_o, n_o - 1, p)
    b = jnp.concatenate([b1, b2], axis=-1)                        # receiver||sender

    e = nn.mlp_apply(params_fr, b.astype(jnp.float32),
                     activation=cfg.activation,
                     compute_dtype=jnp.float32)                   # (B, N_o, N_o-1, D_e)
    return jnp.sum(e, axis=-2).astype(jnp.float32)                # (B, N_o, D_e)
