"""Pallas TPU kernel: fused JEDI-net edge block (Sec. 3.1-3.5 on TPU).

One kernel computes, per batch tile, the node-aggregated edge messages

    Ebar[b, i] = sum_{s != i} f_R(x[b, i] || x[b, s])

without ever materializing the (N_E x 2P) B matrix or the (N_E x D_e) E
matrix in HBM — the TPU analogue of the paper's sub-layer fusion, which on
the FPGA removes the ping-pong buffers between the MMM1/2, Concat, DNN1 and
MMM3 pipeline stages.

Two code transformations go BEYOND the paper (recorded in EXPERIMENTS.md
§Perf as beyond-paper optimizations):

1. *Bilinear first-layer split.*  f_R's first layer acts on the
   concatenation [x_r || x_s], so W1 splits into W1r, W1s with

       h1(r, s) = act(x_r W1r + x_s W1s + b1)

   and the two projections are computed ONCE PER NODE (N_o rows) instead of
   once per edge (N_o*(N_o-1) rows): a (N_o-1)x FLOP reduction on layer 1,
   on top of the paper's MMM elimination.

2. *Dense grid + diagonal correction.*  The paper's strength reduction
   folds the one-hot structure into FPGA loop indices; the TPU equivalent
   of an irregular loop index is a gather, which Mosaic lowers poorly.
   Instead we compute the FULL N_o x N_o interaction grid (including the
   self-edge (i, i)) with perfectly regular, MXU-aligned access and
   subtract the self-message afterwards:

       Ebar[i] = sum_s E[i, s] - E[i, i]

   N_o^2 vs N_o*(N_o-1) messages = 1/(N_o-1) extra compute (~3%) traded
   for zero gathers — the same "avoid irregular memory access" goal as the
   paper, achieved with the opposite mechanism because the hardware cost
   model is inverted (FPGA: wires are free, BRAM ports are not; TPU: dense
   vector lanes are free, gathers are not).

Grid: one program per batch tile; weights are broadcast to every step.
VMEM per step (bb=8, N_o=50, width<=96, fp32):
  x tile 8*50*16*4 = 25.6 KB, grid acts 8*2500*96*4 = 7.7 MB — fits the
  ~16 MB VMEM budget; block_b is autotuned down for wider f_R.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.nn.core import ACTIVATIONS


def _mm(h, w):
    """Matmul with compute-dtype operands and fp32 accumulation."""
    return jax.lax.dot_general(
        h.astype(w.dtype), w,
        (((h.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _edge_block_kernel(x_ref, w1r_ref, w1s_ref, b1_ref, *rest_refs,
                       activation: str, n_layers: int):
    """rest_refs = [w2, b2, w3, b3, ..., out_ref].

    Weight refs arrive pre-cast to the compute dtype (the precision
    co-design knob, ``JediNetConfig.compute_dtype``); biases are fp32 and
    every matmul accumulates fp32 via ``preferred_element_type``.
    """
    out_ref = rest_refs[-1]
    wref = rest_refs[:-1]
    act = ACTIVATIONS[activation]

    x = x_ref[...]                                      # (bb, N_o, P)
    bb, n_o, _ = x.shape

    # --- layer 1, bilinear split: per-node projections (N_o rows, not N_E)
    u_r = _mm(x, w1r_ref[...])                          # (bb, N_o, H1) fp32
    u_s = _mm(x, w1s_ref[...])                          # (bb, N_o, H1) fp32

    # --- dense receiver x sender grid (regular access, no gather)
    h = u_r[:, :, None, :] + u_s[:, None, :, :] + b1_ref[...]
    if n_layers > 1:                                    # f_R output layer is linear
        h = act(h)                                      # (bb, N_o, N_o, H1)

    # --- remaining f_R layers on the flattened grid
    for li in range(n_layers - 1):
        h = _mm(h, wref[2 * li][...]) + wref[2 * li + 1][...]
        if li < n_layers - 2:
            h = act(h)                                  # no act on f_R output

    # --- aggregate: sum over senders minus the self-edge diagonal
    total = jnp.sum(h, axis=2)                          # (bb, N_o, D_e)
    eye = jnp.eye(n_o, dtype=h.dtype)                   # static constant
    diag = jnp.einsum("brsd,rs->brd", h, eye)
    out_ref[...] = (total - diag).astype(out_ref.dtype)


def split_first_layer(params_fr, n_features: int, dtype=jnp.float32):
    """Split f_R's first-layer weight into receiver / sender halves.

    Weights are cast to ``dtype`` (the MXU compute dtype); biases stay
    fp32 so the bias-add happens on the fp32 accumulator.  int8-
    quantized weights keep their integer dtype — the whole-network
    kernel dequantizes them in VMEM (both halves of a split w1 share
    w1's per-tensor scale).
    """
    def wcast(w):
        return w if jnp.issubdtype(w.dtype, jnp.integer) else w.astype(dtype)

    layers = params_fr["layers"]
    w1 = wcast(layers[0]["w"])                          # (2P, H1)
    b1 = layers[0]["b"].astype(jnp.float32)
    w1r, w1s = w1[:n_features], w1[n_features:]
    rest = []
    for lp in layers[1:]:
        rest.append(wcast(lp["w"]))
        rest.append(lp["b"].astype(jnp.float32))
    return w1r, w1s, b1, rest


def fused_edge_block_kernel_call(x, w1r, w1s, b1, rest, *, activation: str,
                                 block_b: int, interpret: bool = False):
    """x: (B, N_o, P) fp32 -> Ebar (B, N_o, D_e) fp32. B % block_b == 0."""
    bsz, n_o, p = x.shape
    assert bsz % block_b == 0, (bsz, block_b)
    n_layers = 1 + len(rest) // 2
    d_e = (rest[-2].shape[-1] if rest else w1r.shape[-1])
    grid = (bsz // block_b,)

    def xmap(i):
        return (i, 0, 0)

    def wmap(*shape_ndim):
        def m(i):
            return (0,) * shape_ndim[0]
        return m

    in_specs = [
        pl.BlockSpec((block_b, n_o, p), xmap),
        pl.BlockSpec(w1r.shape, wmap(w1r.ndim)),
        pl.BlockSpec(w1s.shape, wmap(w1s.ndim)),
        pl.BlockSpec(b1.shape, wmap(b1.ndim)),
    ]
    for r in rest:
        in_specs.append(pl.BlockSpec(r.shape, wmap(r.ndim)))

    kernel = functools.partial(_edge_block_kernel, activation=activation,
                               n_layers=n_layers)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, n_o, d_e), xmap),
        out_shape=jax.ShapeDtypeStruct((bsz, n_o, d_e), jnp.float32),
        interpret=interpret,
    )(x, w1r, w1s, b1, *rest)
