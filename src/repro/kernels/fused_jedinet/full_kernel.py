"""Pallas TPU kernel: whole-network fused JEDI-net forward (x -> logits).

The edge-only kernel (``kernel.py``) fuses MMM1/2 + f_R + MMM3 but still
bounces Ebar, C and O through XLA/HBM for f_O, the node-sum and phi_O.
This kernel extends the paper's Sec 3.5 "divide, conquer, fuse" to ALL
sub-layers: one program instance owns a batch tile and computes

    bilinear-split f_R  ->  dense-grid aggregation  ->  C = [x ‖ Ebar]
        ->  f_O  ->  sum_i O[i]  ->  phi_O  ->  logits

entirely in VMEM.  No intermediate (B, E, Ebar, C, O) ever touches HBM —
the only HBM traffic is the weights + x in and the (batch, n_targets)
logits out, the TPU analogue of the paper's fully-fused layer-wise
architecture where every stage hand-off is an on-chip stream.

Precision co-design (the paper tunes FPGA word lengths; we tune the MXU
input dtype): every matmul casts its operands to ``compute_dtype`` and
accumulates in fp32 via ``preferred_element_type``; biases, activations
and both reductions (sender-sum, node-sum) stay fp32.  With
``compute_dtype="bfloat16"`` the MXU runs at its native rate while the
additive aggregation — the numerically delicate part (up to N_o-1 = 49
summands) — keeps full precision.

The two beyond-paper transformations of the edge kernel (bilinear
first-layer split; dense N_o x N_o grid + diagonal correction instead of
a gather) are inherited unchanged — see kernel.py's docstring and
EXPERIMENTS.md §Perf.

Grid: one program per batch tile, weights broadcast to every step.
``block_b`` comes from the working-set autotuner (autotune.py), which
models the FULL live set (grid + C + f_O acts), not just the f_R grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_jedinet.kernel import _mm
from repro.nn.core import ACTIVATIONS


def _full_forward_kernel(x_ref, *rest_refs, activation: str, n_fr: int,
                         n_fo: int, n_phi: int):
    """rest_refs = [w1r, w1s, b1, (fr w/b)*, (fo w/b)*, (phi w/b)*, out_ref].

    Weight refs arrive pre-cast to the compute dtype; biases are fp32.
    """
    out_ref = rest_refs[-1]
    wref = list(rest_refs[:-1])
    act = ACTIVATIONS[activation]

    w1r, w1s, b1 = wref[0], wref[1], wref[2]
    fr_rest = wref[3:3 + 2 * (n_fr - 1)]
    fo_w = wref[3 + 2 * (n_fr - 1):3 + 2 * (n_fr - 1) + 2 * n_fo]
    phi_w = wref[3 + 2 * (n_fr - 1) + 2 * n_fo:]

    x = x_ref[...]                                      # (bb, N_o, P) cdt
    _, n_o, _ = x.shape

    # --- f_R layer 1, bilinear split: per-node projections (N_o rows)
    u_r = _mm(x, w1r[...])                              # (bb, N_o, H1) fp32
    u_s = _mm(x, w1s[...])

    # --- dense receiver x sender grid (regular access, no gather)
    h = u_r[:, :, None, :] + u_s[:, None, :, :] + b1[...]
    if n_fr > 1:                                        # f_R output is linear
        h = act(h)                                      # (bb, N_o, N_o, H1)

    # --- remaining f_R layers on the grid
    for li in range(n_fr - 1):
        h = _mm(h, fr_rest[2 * li][...]) + fr_rest[2 * li + 1][...]
        if li < n_fr - 2:
            h = act(h)

    # --- aggregate: zero the self-edge diagonal, then sum over senders.
    # Masking BEFORE the sum (instead of subtracting the diagonal after)
    # keeps the summand set identical to the strength-reduced reference —
    # no subtractive cancellation, so fp32 agreement stays < 1e-4.
    mask = 1.0 - jnp.eye(n_o, dtype=h.dtype)
    ebar = jnp.sum(h * mask[None, :, :, None], axis=2)  # (bb, N_o, D_e)

    # --- C = [x ‖ Ebar]; f_O per node, all still in VMEM
    h = jnp.concatenate([x.astype(jnp.float32), ebar], axis=-1)
    for li in range(n_fo):
        h = _mm(h, fo_w[2 * li][...]) + fo_w[2 * li + 1][...]
        if li < n_fo - 1:
            h = act(h)                                  # (bb, N_o, D_o)

    # --- node-sum + phi_O -> logits
    h = jnp.sum(h, axis=1)                              # (bb, D_o) fp32
    for li in range(n_phi):
        h = _mm(h, phi_w[2 * li][...]) + phi_w[2 * li + 1][...]
        if li < n_phi - 1:
            h = act(h)

    out_ref[...] = h.astype(out_ref.dtype)              # (bb, n_targets)


def flatten_mlp(params, dtype):
    """[w0, b0, w1, b1, ...] with weights cast to ``dtype``, biases fp32."""
    flat = []
    for lp in params["layers"]:
        flat.append(lp["w"].astype(dtype))
        flat.append(lp["b"].astype(jnp.float32))
    return flat


def fused_forward_full_kernel_call(x, fr_arrays, fo_arrays, phi_arrays, *,
                                   activation: str, n_targets: int,
                                   block_b: int, interpret: bool = False):
    """x: (B, N_o, P) compute-dtype -> logits (B, n_targets) fp32.

    ``B % block_b == 0`` (callers pad via autotune.pad_batch).
    ``fr_arrays = [w1r, w1s, b1, w2, b2, ...]`` from split_first_layer.
    """
    bsz, n_o, p = x.shape
    assert bsz % block_b == 0, (bsz, block_b)
    n_fr = 1 + (len(fr_arrays) - 3) // 2
    n_fo = len(fo_arrays) // 2
    n_phi = len(phi_arrays) // 2
    weights = [*fr_arrays, *fo_arrays, *phi_arrays]
    grid = (bsz // block_b,)

    def xmap(i):
        return (i, 0, 0)

    def wmap(ndim):
        def m(i):
            return (0,) * ndim
        return m

    in_specs = [pl.BlockSpec((block_b, n_o, p), xmap)]
    for w in weights:
        in_specs.append(pl.BlockSpec(w.shape, wmap(w.ndim)))

    kernel = functools.partial(_full_forward_kernel, activation=activation,
                               n_fr=n_fr, n_fo=n_fo, n_phi=n_phi)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, n_targets), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_targets), jnp.float32),
        interpret=interpret,
    )(x, *weights)
