"""Pallas TPU kernel: whole-network fused JEDI-net forward (x -> logits).

The edge-only kernel (``kernel.py``) fuses MMM1/2 + f_R + MMM3 but still
bounces Ebar, C and O through XLA/HBM for f_O, the node-sum and phi_O.
This kernel extends the paper's Sec 3.5 "divide, conquer, fuse" to ALL
sub-layers: one program instance owns a batch tile and computes

    bilinear-split f_R  ->  dense-grid aggregation  ->  C = [x ‖ Ebar]
        ->  f_O  ->  sum_i O[i]  ->  phi_O  ->  logits

entirely in VMEM.  No intermediate (B, E, Ebar, C, O) ever touches HBM —
the only HBM traffic is the weights + x in and the (batch, n_targets)
logits out, the TPU analogue of the paper's fully-fused layer-wise
architecture where every stage hand-off is an on-chip stream.

Two-level tiling (sender axis)
------------------------------
The f_R interaction grid is the VMEM hog: materializing the full
receiver x sender grid costs ``O(block_b * N_o^2 * H1)`` fp32, which at
N_o=50 already forces tiny batch tiles and past N_o~100 cannot hold even
ONE sample — exactly the regime real-time track-graph building targets
(Neu et al., 2307.07289; JEDI-linear, 2508.15468).  The kernel therefore
grids over (batch tiles, sender tiles): each program step computes the
``(block_b, N_o, block_s, H1)`` slab of the grid for one chunk of
``block_s`` senders and folds its sender-sum into an fp32 VMEM scratch
accumulator ``acc[block_b, N_o, D_e]`` that persists across the sender
steps.  Only after the LAST sender tile does the trailing network
(f_O, node-sum, phi_O) run and write logits.  The live set shrinks from
``O(block_b * N_o^2 * H1)`` to ``O(block_b * N_o * block_s * H1)``, so
``block_b`` grows by ~``N_o / block_s`` — weight traffic amortizes over
much larger batch tiles — and N_o=128 graphs fit where the untiled
working-set model rejects even ``block_b = 1``.

Each sender chunk is SLICED out of the batch tile's resident x block in
VMEM (``block_s`` need not divide N_o: the remainder tile's slice start
clamps and the mask drops the re-covered columns), so x crosses HBM
once per batch tile — the docstring's traffic claim stays exact.  The
diagonal (self-edge) mask and the clamp mask are applied PER TILE
before the accumulate, so the summand set stays identical to the
strength-reduced reference — no subtractive cancellation, fp32
agreement < 1e-4.  ``block_s = N_o`` degenerates to the old untiled
kernel (one sender step, mask = 1 - eye).

In-kernel int8 weights
----------------------
Weight refs may arrive as int8 (symmetric per-tensor quantization,
``core/int8_path.py``): the kernel then loads 1-byte weights from HBM
into VMEM, runs the matmul on the raw integer values upcast to the
compute dtype, and folds the fp32 ``scale`` into the ACCUMULATED fp32
result — numerically the dequantized matmul, billed at 1 B/weight HBM
traffic (``PathSpec.weight_bytes = 1``).  Scales ride in one small
``(1, n_weights)`` fp32 input; biases stay fp32 and are added after the
scale fold, exactly as in the fp path.

Precision co-design (the paper tunes FPGA word lengths; we tune the MXU
input dtype): every matmul casts its operands to ``compute_dtype`` and
accumulates in fp32 via ``preferred_element_type``; biases, activations
and both reductions (sender-sum, node-sum) stay fp32.

The two beyond-paper transformations of the edge kernel (bilinear
first-layer split; dense grid + diagonal/bounds masking instead of a
gather) are inherited — see kernel.py's docstring and EXPERIMENTS.md
§Perf.

Grid: ``(batch tiles, sender tiles)``, sender innermost; weights and
scales broadcast to every step.  ``(block_b, block_s)`` come from the 2D
working-set autotuner (autotune.pick_block_b_s), which models the TILED
live set.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.nn.core import ACTIVATIONS


def _is_int(w) -> bool:
    return jnp.issubdtype(w.dtype, jnp.integer)


def _mmq(h, w, scale, compute_dtype):
    """Matmul with fp32 accumulation; int weights fold ``scale`` AFTER.

    ``h`` casts to the weight's compute representation (int8 weights
    upcast to ``compute_dtype`` — their integer values are exact in
    fp32/bf16 up to +-127, so the MXU sees the same operands an int8
    datapath would); the per-tensor dequant scale multiplies the fp32
    ACCUMULATOR, not the weight, so the weight block in VMEM stays
    1 byte/element.
    """
    wv = w[...]
    if _is_int(wv):
        wv = wv.astype(compute_dtype)
    out = jax.lax.dot_general(
        h.astype(wv.dtype), wv,
        (((h.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if scale is not None:
        out = out * scale
    return out


def _tiled_forward_kernel(x_ref, *rest_refs, activation: str,
                          n_fr: int, n_fo: int, n_phi: int, n_o: int,
                          block_s: int, quantized: bool, compute_dtype):
    """rest_refs = [scales?] + [w1r, w1s, b1, (fr w/b)*, (fo w/b)*,
    (phi w/b)*] + [out_ref, acc_ref].

    ``x_ref``   — (block_b, N_o, P): the full receiver view, resident
                  across sender steps (its index map ignores j), so x
                  crosses HBM ONCE per batch tile.  Each sender step
                  slices its ``block_s`` chunk out of this block in
                  VMEM — no second x operand, no sender-padded copy.
                  The slice start clamps at ``N_o - block_s`` for the
                  remainder tile; the mask excludes the senders the
                  clamp re-covers (``send >= j*block_s``).
    ``acc_ref`` — (block_b, N_o, D_e) fp32 VMEM scratch: the Ebar
                  accumulator, carried across the sender steps of one
                  batch tile.
    Weight refs arrive pre-cast to the compute dtype (or int8 when
    ``quantized``); biases are fp32.
    """
    out_ref, acc_ref = rest_refs[-2], rest_refs[-1]
    wref = list(rest_refs[:-2])
    if quantized:
        scales_ref, wref = wref[0], wref[1:]

        def s(k):
            return scales_ref[0, k]
    else:
        def s(k):
            return None
    act = ACTIVATIONS[activation]

    w1r, w1s, b1 = wref[0], wref[1], wref[2]
    fr_rest = wref[3:3 + 2 * (n_fr - 1)]
    fo_w = wref[3 + 2 * (n_fr - 1):3 + 2 * (n_fr - 1) + 2 * n_fo]
    phi_w = wref[3 + 2 * (n_fr - 1) + 2 * n_fo:]
    # scale index of each weight tensor, in ref order (biases carry none)
    k_fr = list(range(n_fr + 1))                       # w1r, w1s, w2..
    k_fo = [n_fr + 1 + i for i in range(n_fo)]
    k_phi = [n_fr + 1 + n_fo + i for i in range(n_phi)]

    j = pl.program_id(1)
    n_sj = pl.num_programs(1)

    x = x_ref[...]                                      # (bb, N_o, P) cdt
    # this step's sender chunk, sliced from the resident receiver block;
    # the start clamps for the remainder tile (block_s ∤ N_o) and the
    # mask below drops the rows the clamp re-reads from the previous tile
    start = jnp.minimum(j * block_s, n_o - block_s)
    xs = jax.lax.dynamic_slice_in_dim(x, start, block_s, axis=1)

    # --- f_R layer 1, bilinear split: receiver projection over ALL N_o
    # rows (cheap: N_o*P*H1, recomputed per sender step so no second
    # scratch), sender projection over THIS tile only.
    u_r = _mmq(x, w1r, s(k_fr[0]), compute_dtype)       # (bb, N_o, H1) fp32
    u_s = _mmq(xs, w1s, s(k_fr[1]), compute_dtype)      # (bb, bs, H1) fp32

    # --- dense receiver x sender-tile slab (regular access, no gather)
    h = u_r[:, :, None, :] + u_s[:, None, :, :] + b1[...]
    if n_fr > 1:                                        # f_R output is linear
        h = act(h)                                      # (bb, N_o, bs, H1)

    # --- remaining f_R layers on the slab
    for li in range(n_fr - 1):
        h = _mmq(h, fr_rest[2 * li], s(k_fr[2 + li]), compute_dtype) \
            + fr_rest[2 * li + 1][...]
        if li < n_fr - 2:
            h = act(h)

    # --- masked accumulate: zero the self-edge diagonal cell AND any
    # sender column the clamped remainder slice re-covers from the
    # previous tile, BEFORE the sum — every sender contributes exactly
    # once and the summand set stays identical to the reference (no
    # subtractive cancellation).
    recv = jax.lax.broadcasted_iota(jnp.int32, (n_o, block_s), 0)
    send = jax.lax.broadcasted_iota(jnp.int32, (n_o, block_s), 1) + start
    mask = ((recv != send) & (send >= j * block_s)).astype(h.dtype)
    contrib = jnp.sum(h * mask[None, :, :, None], axis=2)   # (bb, N_o, D_e)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += contrib

    # --- after the LAST sender tile: C = [x ‖ Ebar], f_O, node-sum,
    # phi_O — all still in VMEM, once per batch tile.
    @pl.when(j == n_sj - 1)
    def _tail():
        h = jnp.concatenate([x.astype(jnp.float32), acc_ref[...]], axis=-1)
        for li in range(n_fo):
            h_ = _mmq(h, fo_w[2 * li], s(k_fo[li]), compute_dtype) \
                + fo_w[2 * li + 1][...]
            h_ = act(h_) if li < n_fo - 1 else h_       # (bb, N_o, D_o)
            h = h_
        h = jnp.sum(h, axis=1)                          # (bb, D_o) fp32
        for li in range(n_phi):
            h_ = _mmq(h, phi_w[2 * li], s(k_phi[li]), compute_dtype) \
                + phi_w[2 * li + 1][...]
            h_ = act(h_) if li < n_phi - 1 else h_
            h = h_
        out_ref[...] = h.astype(out_ref.dtype)          # (bb, n_targets)


def flatten_mlp(params, dtype):
    """[w0, b0, w1, b1, ...] with weights cast to ``dtype``, biases fp32.

    int8-quantized layers (``{"w": int8, "w_scale": fp32, "b": fp32}``)
    keep their int8 weights verbatim — the kernel dequantizes in VMEM.
    """
    flat = []
    for lp in params["layers"]:
        w = lp["w"]
        flat.append(w if _is_int(w) else w.astype(dtype))
        flat.append(lp["b"].astype(jnp.float32))
    return flat


def mlp_scales(params) -> list:
    """Per-layer dequant scales of a quantized MLP (fp32 scalars)."""
    return [lp["w_scale"] for lp in params["layers"]]


def fused_forward_full_kernel_call(x, fr_arrays, fo_arrays, phi_arrays, *,
                                   activation: str, n_targets: int,
                                   block_b: int, block_s: int | None = None,
                                   scales=None, interpret: bool = False):
    """x: (B, N_o, P) compute-dtype -> logits (B, n_targets) fp32.

    ``B % block_b == 0`` (callers pad via autotune.pad_batch).
    ``fr_arrays = [w1r, w1s, b1, w2, b2, ...]`` from split_first_layer.
    ``block_s`` tiles the sender axis (default N_o = untiled).
    ``scales`` — fp32 vector of per-weight-tensor dequant scales, in
    weight order [w1r, w1s, w2.., fo.., phi..], required iff any weight
    array is an integer dtype (in-kernel int8 dequant).
    """
    bsz, n_o, p = x.shape
    block_s = n_o if block_s is None else min(int(block_s), n_o)
    n_fr = 1 + (len(fr_arrays) - 3) // 2
    n_fo = len(fo_arrays) // 2
    n_phi = len(phi_arrays) // 2
    weights = [*fr_arrays, *fo_arrays, *phi_arrays]
    quantized = any(_is_int(w) for w in weights)
    d_e = fr_arrays[-2].shape[-1] if n_fr > 1 else fr_arrays[0].shape[-1]
    compute_dtype = x.dtype

    if bsz % block_b != 0:
        from repro.kernels.fused_jedinet import autotune as fj_autotune
        fr_w = [int(w.shape[-1]) for w in fr_arrays[0:1] + fr_arrays[3::2]]
        fo_w = [int(w.shape[-1]) for w in fo_arrays[0::2]]
        phi_w = [int(w.shape[-1]) for w in phi_arrays[0::2]]
        modeled = fj_autotune.full_forward_tiled_bytes_per_sample(
            n_o, p, fr_w, fo_w, phi_w, block_s)
        raise ValueError(
            f"batch {bsz} is not a multiple of the batch tile: autotuned "
            f"(block_b={block_b}, block_s={block_s}) at modeled {modeled} "
            f"VMEM bytes/sample — pad the batch with autotune.pad_batch(x, "
            f"{block_b}) (kernel wrappers do this automatically)")
    if quantized:
        n_w = len(weights) // 2 + 1                  # +1: w1 split in two
        if scales is None:
            raise ValueError(
                "int8 weight arrays need their dequant scales: pass "
                "scales=[s_w1r, s_w1s, s_w2, ...] (one per weight tensor)")
        scales = jnp.asarray(scales, jnp.float32).reshape(1, -1)
        if scales.shape[1] != n_w:
            raise ValueError(
                f"got {scales.shape[1]} scales for {n_w} weight tensors")

    n_sj = -(-n_o // block_s)
    grid = (bsz // block_b, n_sj)

    def wmap(ndim):
        def m(i, j):
            return (0,) * ndim
        return m

    in_specs = [pl.BlockSpec((block_b, n_o, p), lambda i, j: (i, 0, 0))]
    operands = [x]
    if quantized:
        in_specs.append(pl.BlockSpec(scales.shape, wmap(scales.ndim)))
        operands.append(scales)
    for w in weights:
        in_specs.append(pl.BlockSpec(w.shape, wmap(w.ndim)))
    operands.extend(weights)

    kernel = functools.partial(
        _tiled_forward_kernel, activation=activation, n_fr=n_fr, n_fo=n_fo,
        n_phi=n_phi, n_o=n_o, block_s=block_s, quantized=quantized,
        compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, n_targets), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_targets), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, n_o, d_e), jnp.float32)],
        interpret=interpret,
    )(*operands)
