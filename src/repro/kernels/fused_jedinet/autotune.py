"""VMEM working-set estimators for the fused JEDI-net kernels.

Both fused kernels (edge-only and whole-network) are gridded over the
batch axis only: one program instance owns ``block_b`` jets and every
intermediate for those jets lives in VMEM.  Choosing ``block_b`` is
therefore a pure working-set computation — the per-sample VMEM bytes of
the LARGEST live intermediate chain — fed to the shared tile picker in
``repro.kernels.autotune``.

This replaces the ad-hoc ``_pick_block_b`` that used to live in
``ops.py``.  Two behavioural fixes over that version:

* The edge-only estimate ignored everything but the f_R grid; the full
  kernel also keeps C, the f_O activations and the phi_O activations
  live, so the working set is modelled per kernel from the actual layer
  widths.
* The old picker rounded ``block_b`` down to a *divisor of the batch*
  so the grid tiled exactly.  A prime batch (B=1009) therefore degraded
  to ``block_b=1`` — a 1009-step grid of tiny tiles.  The shared picker
  keeps the VMEM-optimal tile and PADS the batch to the next tile
  multiple (callers slice the output back); worst-case padding overhead
  is (block_b-1)/B — sub-percent for any realistic trigger batch —
  versus up to a block_b-times larger grid.
"""

from __future__ import annotations

# Re-exported so kernel wrappers and tests have one import surface.
from repro.kernels.autotune import (  # noqa: F401
    VMEM_BUDGET_BYTES,
    _SUBLANE,
    mlp_widths,
    pad_batch,
    padded_batch,
    pick_block_b,
)


def edge_block_bytes_per_sample(n_objects: int, n_features: int,
                                fr_widths: list[int],
                                acc_bytes: int = 4) -> int:
    """Per-jet VMEM working set of the edge-only kernel (fp32 accumulation).

    Dominated by the dense (N_o, N_o, width) interaction grid; the x tile
    and the Ebar output tile ride along.
    """
    n_o = n_objects
    grid = n_o * n_o * max(fr_widths + [_SUBLANE])
    x_tile = n_o * n_features
    out_tile = n_o * fr_widths[-1]
    return (grid + x_tile + out_tile) * acc_bytes


def full_forward_bytes_per_sample(n_objects: int, n_features: int,
                                  fr_widths: list[int],
                                  fo_widths: list[int],
                                  phi_widths: list[int],
                                  acc_bytes: int = 4) -> int:
    """Per-jet VMEM working set of the whole-network kernel.

    The f_R grid still dominates, but C = [x ‖ Ebar], the f_O activations
    and the (per-tile negligible) phi_O activations are live in the same
    program, so they count against the same budget.
    """
    n_o = n_objects
    grid = n_o * n_o * max(fr_widths + [_SUBLANE])
    x_tile = n_o * n_features
    ebar = n_o * fr_widths[-1]
    c_tile = n_o * (n_features + fr_widths[-1])
    fo_acts = n_o * max(fo_widths + [_SUBLANE])
    phi_acts = max(phi_widths + [_SUBLANE])
    return (grid + x_tile + ebar + c_tile + fo_acts + phi_acts) * acc_bytes
