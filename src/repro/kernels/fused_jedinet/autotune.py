"""VMEM working-set estimators for the fused JEDI-net kernels.

Both fused kernels are gridded over the batch axis (the whole-network
kernel additionally over sender tiles): one program instance owns
``block_b`` jets and every intermediate for those jets lives in VMEM.
Choosing the tile sizes is therefore a pure working-set computation —
the per-sample VMEM bytes of the LARGEST live intermediate chain — fed
to the shared tile picker in ``repro.kernels.autotune``.

Three estimators:

* :func:`edge_block_bytes_per_sample` — edge-only kernel (f_R grid
  dominates; x and Ebar tiles ride along).
* :func:`full_forward_bytes_per_sample` — UNTILED whole-network kernel:
  the full ``(N_o, N_o, H1)`` receiver x sender grid is live at once.
  Kept as the rejection model for large graphs — past N_o ~ 100 even
  ``block_b = 1`` exceeds the budget (:func:`fits_vmem`), which is the
  regime the sender-tiled kernel exists for.
* :func:`full_forward_tiled_bytes_per_sample` — sender-tiled kernel:
  only a ``(N_o, block_s, H1)`` slab of the grid plus the fp32 Ebar
  accumulator is live, so the per-sample set shrinks ~``N_o/block_s``
  and ``block_b`` grows by the ratio.

:func:`pick_block_b_s` searches the 2D ``(block_b, block_s)`` space:
smaller sender tiles buy larger batch tiles (weight HBM traffic
amortizes over more jets per step), so the picker maximizes ``block_b``
and breaks ties toward the larger ``block_s`` (fewer sender steps, less
remainder padding).  For batches small enough that the whole batch fits
at every ``block_s``, the tie-break degenerates to ``block_s = N_o`` —
the untiled kernel, with zero sender-loop overhead.
"""

from __future__ import annotations

# Re-exported so kernel wrappers and tests have one import surface.
from repro.kernels.autotune import (  # noqa: F401
    VMEM_BUDGET_BYTES,
    _SUBLANE,
    effective_budget,
    mlp_widths,
    pad_batch,
    padded_batch,
    pick_block_b,
    weight_vmem_bytes,
)


def edge_block_bytes_per_sample(n_objects: int, n_features: int,
                                fr_widths: list[int],
                                acc_bytes: int = 4) -> int:
    """Per-jet VMEM working set of the edge-only kernel (fp32 accumulation).

    Dominated by the dense (N_o, N_o, width) interaction grid; the x tile
    and the Ebar output tile ride along.
    """
    n_o = n_objects
    grid = n_o * n_o * max(fr_widths + [_SUBLANE])
    x_tile = n_o * n_features
    out_tile = n_o * fr_widths[-1]
    return (grid + x_tile + out_tile) * acc_bytes


def full_forward_bytes_per_sample(n_objects: int, n_features: int,
                                  fr_widths: list[int],
                                  fo_widths: list[int],
                                  phi_widths: list[int],
                                  acc_bytes: int = 4) -> int:
    """Per-jet VMEM working set of the UNTILED whole-network kernel.

    The full (N_o, N_o, H1) f_R grid is live at once; C = [x ‖ Ebar],
    the f_O activations and the (per-tile negligible) phi_O activations
    are live in the same program, so they count against the same budget.
    This is the model that REJECTS large graphs (see :func:`fits_vmem`);
    the tiled estimate below is what the kernel actually runs under.
    """
    return full_forward_tiled_bytes_per_sample(
        n_objects, n_features, fr_widths, fo_widths, phi_widths,
        block_s=n_objects, acc_bytes=acc_bytes)


def full_forward_tiled_bytes_per_sample(n_objects: int, n_features: int,
                                        fr_widths: list[int],
                                        fo_widths: list[int],
                                        phi_widths: list[int],
                                        block_s: int,
                                        acc_bytes: int = 4) -> int:
    """Per-jet VMEM working set of the sender-tiled whole-network kernel.

    Live at any instant: one (N_o, block_s, H1) slab of the f_R grid,
    the bilinear-split projections u_r (N_o, H1) / u_s (block_s, H1)
    feeding it, the fp32 Ebar accumulator scratch, the receiver x tile
    plus this step's sender-chunk slice, and — only after the last
    sender tile — C and the f_O / phi_O activations.  The tail
    intermediates share the budget because they coexist with the
    accumulator and x.  ``block_s = N_o`` reproduces the untiled
    estimate exactly.
    """
    n_o = n_objects
    block_s = max(1, min(int(block_s), n_o))
    h1 = fr_widths[0]
    slab = n_o * block_s * max(fr_widths + [_SUBLANE])
    u_r = n_o * h1
    u_s = block_s * h1
    x_tile = n_o * n_features
    xs_tile = block_s * n_features
    ebar_acc = n_o * fr_widths[-1]
    c_tile = n_o * (n_features + fr_widths[-1])
    fo_acts = n_o * max(fo_widths + [_SUBLANE])
    phi_acts = max(phi_widths + [_SUBLANE])
    return (slab + u_r + u_s + x_tile + xs_tile + ebar_acc + c_tile
            + fo_acts + phi_acts) * acc_bytes


def fits_vmem(per_sample_bytes: int,
              budget_bytes: int = VMEM_BUDGET_BYTES) -> bool:
    """Can even ONE sample's working set hold the budget?  ``False``
    means the kernel under that model OOMs VMEM at any batch tile —
    the untiled whole-network kernel past N_o ~ 100."""
    return per_sample_bytes <= budget_bytes


def sender_tile_candidates(n_objects: int) -> list[int]:
    """Sender-axis tile sizes worth searching: sublane-aligned doublings
    (8, 16, 32, ...) strictly below N_o, plus N_o itself (the untiled
    degenerate).  Ascending."""
    cands = []
    b = _SUBLANE
    while b < n_objects:
        cands.append(b)
        b *= 2
    cands.append(n_objects)
    return cands


def pick_block_b_s(batch: int, n_objects: int, n_features: int,
                   fr_widths: list[int], fo_widths: list[int],
                   phi_widths: list[int],
                   budget_bytes: int = VMEM_BUDGET_BYTES,
                   reserved_bytes: int = 0) -> tuple[int, int]:
    """Jointly pick ``(block_b, block_s)`` for the tiled kernel.

    For each candidate sender tile the per-sample live set is modeled
    (:func:`full_forward_tiled_bytes_per_sample`) and the shared picker
    chooses the batch tile; the winner maximizes ``block_b`` (weight
    traffic amortizes over the largest batch tile), ties broken toward
    the LARGER ``block_s`` (fewer sender grid steps, less remainder
    padding — and for small batches this degenerates to
    ``block_s = N_o``, the untiled kernel).

    ``reserved_bytes`` (e.g. the weight blocks' VMEM residency,
    :func:`~repro.kernels.autotune.weight_vmem_bytes`) is subtracted
    from the budget — the quantization-aware knob: int8 weights reserve
    4x less, leaving more VMEM for batch rows.
    """
    budget = effective_budget(budget_bytes, reserved_bytes)
    best = fallback = None
    for bs in sender_tile_candidates(n_objects):
        per = full_forward_tiled_bytes_per_sample(
            n_objects, n_features, fr_widths, fo_widths, phi_widths, bs)
        bb = pick_block_b(batch, per, budget)
        # pick_block_b floors block_b at 1 even when ONE sample busts the
        # budget, so a non-fitting candidate can tie with fitting ones at
        # small batches (and the larger-block_s tie-break would then pick
        # the very configuration fits_vmem rejects) — skip it.
        if per > budget:
            if fallback is None:          # smallest live set, if nothing fits
                fallback = (bb, bs)
            continue
        if best is None or (bb, bs) > (best[0], best[1]):
            best = (bb, bs)
    return best if best is not None else fallback


def modeled_residency(cfg, params, batch: int, *,
                      block_b: int | None = None,
                      block_s: int | None = None,
                      budget_bytes: int = VMEM_BUDGET_BYTES) -> dict:
    """The tiling decision :func:`ops.fused_forward_full` will make for
    ``batch`` samples, as data — the modeled-residency introspection
    hook the kernel-contract auditor (``repro.analysis.kernel_audit``)
    cross-checks against the *traced* ``pallas_call``.

    Mirrors the wrapper's tuner invocation EXACTLY (including the
    pinned-knob branches): any drift between this mirror and the real
    BlockSpecs/grid is precisely the silent-bug class the auditor
    exists to catch, so keep the two in lockstep.

    Returns ``{kernel, block_b, block_s, grid, per_sample_bytes,
    reserved_bytes, effective_budget, weight_residency_bytes, fits}``;
    ``weight_residency_bytes`` is the VMEM the weight blocks (and, for
    quantized params, the dequant-scale vector) occupy at the dtypes the
    kernel ships — what the traced input BlockSpecs must add up to.
    """
    fr_w = mlp_widths(params["fr"])
    fo_w = mlp_widths(params["fo"])
    phi_w = mlp_widths(params["phi"])
    n_o, n_f = cfg.n_objects, cfg.n_features
    reserved = weight_vmem_bytes(params, cfg.compute_dtype)
    if block_b is None and block_s is None:
        block_b, block_s = pick_block_b_s(
            batch, n_o, n_f, fr_w, fo_w, phi_w,
            budget_bytes=budget_bytes, reserved_bytes=reserved)
    elif block_b is None:
        block_s = min(int(block_s), n_o)
        per = full_forward_tiled_bytes_per_sample(
            n_o, n_f, fr_w, fo_w, phi_w, block_s)
        block_b = pick_block_b(batch, per,
                               effective_budget(budget_bytes, reserved))
    elif block_s is None:
        block_s = pick_block_s(block_b, n_o, n_f, fr_w, fo_w, phi_w,
                               budget_bytes=budget_bytes,
                               reserved_bytes=reserved)
    else:
        block_s = min(int(block_s), n_o)
    per = full_forward_tiled_bytes_per_sample(
        n_o, n_f, fr_w, fo_w, phi_w, block_s)
    budget = effective_budget(budget_bytes, reserved)
    return {
        "kernel": "fused_jedinet.full",
        "block_b": int(block_b),
        "block_s": int(block_s),
        "grid": (padded_batch(batch, block_b) // block_b,
                 -(-n_o // block_s)),
        "per_sample_bytes": int(per),
        "reserved_bytes": int(reserved),
        "effective_budget": int(budget),
        "weight_residency_bytes": int(reserved),
        "fits": fits_vmem(per, budget),
    }


def modeled_residency_edge(cfg, params, batch: int, *,
                           block_b: int | None = None,
                           budget_bytes: int = VMEM_BUDGET_BYTES) -> dict:
    """:func:`modeled_residency` twin for the edge-only kernel
    (:func:`ops.fused_edge_block`): batch-gridded only, tile picked from
    :func:`edge_block_bytes_per_sample` with NO weight reservation
    (mirroring the wrapper), and only the f_R weights ship to VMEM."""
    fr_w = mlp_widths(params["fr"])
    per = edge_block_bytes_per_sample(cfg.n_objects, cfg.n_features, fr_w)
    if block_b is None:
        block_b = pick_block_b(batch, per, budget_bytes)
    weights = weight_vmem_bytes({"fr": params["fr"]}, cfg.compute_dtype)
    return {
        "kernel": "fused_jedinet.edge",
        "block_b": int(block_b),
        "block_s": None,
        "grid": (padded_batch(batch, block_b) // block_b,),
        "per_sample_bytes": int(per),
        "reserved_bytes": 0,
        "effective_budget": int(budget_bytes),
        "weight_residency_bytes": int(weights),
        "fits": fits_vmem(per, budget_bytes),
    }


def pick_block_s(block_b: int, n_objects: int, n_features: int,
                 fr_widths: list[int], fo_widths: list[int],
                 phi_widths: list[int],
                 budget_bytes: int = VMEM_BUDGET_BYTES,
                 reserved_bytes: int = 0) -> int:
    """Largest sender tile that fits the budget ALONGSIDE a pinned batch
    tile — the one-knob-pinned complement of :func:`pick_block_b_s`.
    Falls back to the smallest candidate when none fit (the caller's
    ``block_b`` is then oversubscribed either way; the smallest live set
    is the least-bad tile to run it with)."""
    budget = effective_budget(budget_bytes, reserved_bytes)
    cands = sender_tile_candidates(n_objects)
    best = cands[0]
    for bs in cands:                       # per-sample grows with bs, so
        per = full_forward_tiled_bytes_per_sample(   # the last fit wins
            n_objects, n_features, fr_widths, fo_widths, phi_widths, bs)
        if max(int(block_b), 1) * per <= budget:
            best = bs
    return best
