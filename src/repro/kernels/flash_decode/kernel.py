"""Pallas TPU kernel: flash decode — one-token GQA attention over a KV cache.

The decode_32k / long_500k serving cells are memory-bound: each step reads
the whole (B, S, Hkv, D) cache once to produce (B, H, D) outputs.  The
roofline goal is therefore to touch every cache byte exactly once at full
HBM bandwidth.  The kernel tiles the cache sequence axis into VMEM-sized
chunks and keeps the FlashAttention online-softmax carry (m, l, acc) in
VMEM scratch across sequential grid steps — the (G, S) score matrix never
exists in HBM, and each (b, h) stream is one pass over its cache shard.

Grid: (B, Hkv, S/chunk); the chunk axis is the innermost (sequential on
TPU), so scratch carries are valid; (B, Hkv) are parallel.

This is the serving-path cousin of the paper's fusion argument: the FPGA
design fuses pipeline stages to avoid ping-pong buffers between them; here
we fuse score/softmax/weighted-sum to avoid HBM round-trips between them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, qpos_ref, kvpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, n_chunks: int, window):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, D), pre-scaled
    k = k_ref[0, :, 0].astype(jnp.float32)              # (C, D)
    v = v_ref[0, :, 0].astype(jnp.float32)              # (C, D)
    qp = qpos_ref[0]                                    # scalar int32
    kp = kvpos_ref[0]                                   # (C,) int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, C)
    ok = (kp >= 0) & (kp <= qp)
    if window is not None:
        ok &= (qp - kp) < window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_old = m_scr[...]                                  # (G, 1)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                              # (G, C)
    corr = jnp.exp(m_old - m_new)                       # (G, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (G, D)
    m_scr[...] = m_new

    @pl.when(c == n_chunks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_kernel_call(q, k, v, q_pos, kv_pos, *, chunk: int,
                             window=None, interpret: bool = False):
    """q: (B, Hkv, G, D) pre-scaled; k/v: (B, S, Hkv, D); S % chunk == 0."""
    b, hkv, g, d = q.shape
    s_len = k.shape[1]
    assert s_len % chunk == 0, (s_len, chunk)
    n_chunks = s_len // chunk
    grid = (b, hkv, n_chunks)

    kernel = functools.partial(_decode_kernel, n_chunks=n_chunks,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, c_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, chunk, 1, d), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1, d), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (b_,)),
            pl.BlockSpec((1, chunk), lambda b_, h_, c_: (b_, c_)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, c_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos)
