"""Pure-jnp oracle for the flash-decode kernel (one-token GQA attention)."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_ref(q, k, v, q_pos, kv_pos, *, window=None):
    """q: (B, Hkv, G, D) pre-scaled; k/v: (B, S, Hkv, D);
    q_pos: (B,) int32; kv_pos: (B, S) int32 (-1 invalid).
    Returns (B, Hkv, G, D) float32.
    """
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    ok = kv_pos >= 0
    ok &= kv_pos <= q_pos[:, None]
    if window is not None:
        ok &= (q_pos[:, None] - kv_pos) < window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o / jnp.maximum(l, 1e-30)
