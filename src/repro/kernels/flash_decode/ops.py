"""Jit'd public wrapper for the flash-decode kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode import kernel as K


def _pick_chunk(s_len: int, d: int) -> int:
    """Largest cache chunk with k+v fp32 tiles within ~4 MB VMEM."""
    budget = 4 * 1024 * 1024
    c = max(128, min(s_len, budget // max(2 * d * 4, 1)))
    while s_len % c:
        c -= 1
    return c


@partial(jax.jit, static_argnames=("window", "chunk", "interpret"))
def flash_decode(q, k, v, q_pos, kv_pos, *, window=None, chunk=None,
                 interpret: bool = False):
    """One-token GQA attention over a KV cache.

    q: (B, H, D) UNscaled; k/v: (B, S, Hkv, D); q_pos: (B,) int32;
    kv_pos: (B, S) int32, -1 for unwritten slots.  Returns (B, H, D)
    in q.dtype's float32 accumulation.
    """
    b, h, d = q.shape
    hkv = k.shape[2]
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    scale = 1.0 / (d ** 0.5)
    qg = (q.astype(jnp.float32) * scale).reshape(b, hkv, g, d)
    c = chunk or _pick_chunk(k.shape[1], d)
    o = K.flash_decode_kernel_call(qg, k, v, q_pos.astype(jnp.int32),
                                   kv_pos.astype(jnp.int32), chunk=c,
                                   window=window, interpret=interpret)
    return o.reshape(b, h, d)
