"""Jit'd public wrapper for the FM interaction kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import autotune
from repro.kernels.fm_interaction import kernel as K


@partial(jax.jit, static_argnames=("interpret", "block_b"))
def fm_interaction(v, *, interpret: bool = False, block_b: int | None = None):
    """v: (B, F, K) per-field embeddings -> (B,) pairwise-interaction term."""
    bsz, f, k = v.shape
    bb = block_b or autotune.pick_block_b(bsz, f * k * 4)
    vp = autotune.pad_batch(v, bb)
    return K.fm_interaction_kernel_call(vp, block_b=bb,
                                        interpret=interpret)[:bsz]
