"""Jit'd public wrapper for the FM interaction kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.fm_interaction import kernel as K


def _pick_block_b(bsz: int, f: int, k: int) -> int:
    budget = 8 * 1024 * 1024
    bb = max(1, min(bsz, budget // max(f * k * 4, 1)))
    while bsz % bb:
        bb -= 1
    return bb


@partial(jax.jit, static_argnames=("interpret", "block_b"))
def fm_interaction(v, *, interpret: bool = False, block_b: int | None = None):
    """v: (B, F, K) per-field embeddings -> (B,) pairwise-interaction term."""
    bb = block_b or _pick_block_b(*v.shape)
    return K.fm_interaction_kernel_call(v, block_b=bb, interpret=interpret)
