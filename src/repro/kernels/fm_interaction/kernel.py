"""Pallas TPU kernel: FM pairwise interaction via the sum-square identity.

The serve_bulk cell scores 262k samples x 39 fields x 10 dims: the naive
pairwise form is O(F^2 K) with a (B, F, F) intermediate; the sum-square
strength reduction is O(F K) with no intermediate — the recsys twin of the
paper's MMM elimination.  The kernel fuses both reductions (over F, then
over K) in VMEM so the (B, K) sum/sumsq intermediates never reach HBM;
arithmetic intensity is raised from 2 reads/sample-element to exactly 1.

Grid: one program per batch tile; out is a (bb, 1) column (TPU needs a
lane dimension on outputs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fm_kernel(v_ref, o_ref):
    v = v_ref[...].astype(jnp.float32)                  # (bb, F, K)
    sum_v = jnp.sum(v, axis=1)                          # (bb, K)
    sum_sq = jnp.sum(v * v, axis=1)                     # (bb, K)
    out = 0.5 * jnp.sum(sum_v * sum_v - sum_sq, axis=-1)  # (bb,)
    o_ref[...] = out[:, None]


def fm_interaction_kernel_call(v, *, block_b: int, interpret: bool = False):
    """v: (B, F, K) -> (B,) float32; B % block_b == 0."""
    bsz, f, k = v.shape
    assert bsz % block_b == 0, (bsz, block_b)
    grid = (bsz // block_b,)
    out = pl.pallas_call(
        _fm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, f, k), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1), jnp.float32),
        interpret=interpret,
    )(v)
    return out[:, 0]
