"""Pure-jnp oracle for the FM interaction kernel."""

from __future__ import annotations

import jax.numpy as jnp


def fm_interaction_ref(v):
    """v: (B, F, K) -> (B,) float32: sum_{i<j} <v_i, v_j>."""
    v = v.astype(jnp.float32)
    sum_v = jnp.sum(v, axis=-2)
    sum_sq = jnp.sum(jnp.square(v), axis=-2)
    return 0.5 * jnp.sum(jnp.square(sum_v) - sum_sq, axis=-1)
