"""JEDI-linear fused kernel package: O(N_o) interaction aggregation.

JEDI-linear (arXiv 2508.15468, PAPERS.md) keeps f_R's FIRST layer linear
so the pairwise message sum commutes with it: the N_o x (N_o-1) edge
grid collapses into globally-pooled sender projections and the whole
forward runs in O(N_o) FLOPs instead of O(N_o^2).  Modules:

* ``ref.py``           — pure-JAX forwards: the O(N_o) pooled path and
  its O(N_o^2) edge-sum oracle (the numerical spec the pooling identity
  is validated against).
* ``linear_kernel.py`` — the fused Pallas TPU kernel (x -> logits
  on-chip, batch-tiled, in-kernel int8 dequant).
* ``ops.py``           — jit'd public wrapper with autotuned batch
  tiles and pad-to-tile batching.
* ``autotune.py``      — the linear-live-set VMEM model (no sender
  axis: the per-sample working set drops from O(N_o * block_s * H1)
  to O(N_o * H1)).

The paths themselves register in ``repro.core.jedi_linear_path``.
"""
