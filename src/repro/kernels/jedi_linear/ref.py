"""Pure-JAX JEDI-linear forwards: O(N_o) aggregation + its edge-sum oracle.

JEDI-net's f_R applies a nonlinear MLP to every (receiver, sender) pair
before aggregating, so the edge grid is irreducible: O(N_o^2) FLOPs.
JEDI-linear (arXiv 2508.15468) makes f_R's FIRST layer linear, and a
linear map commutes with the sum over senders — the aggregation moves
IN FRONT of the first nonlinearity and the grid telescopes:

    Ebar1_i = sum_{j != i} (W_r x_i + W_s x_j + b1)
            = (N_o - 1) (W_r x_i + b1) + (sum_j W_s x_j - W_s x_i)

i.e. two per-node projections ``u_r = x @ W_r`` / ``u_s = x @ W_s``, ONE
global pool of ``u_s``, and a per-node recombination — O(N_o) where the
grid costs O(N_o^2).  The remaining f_R layers then run per NODE (the
(B, N_o, H1) tensor) instead of per edge, which is where the FLOPs
actually collapse.  This is a DIFFERENT model from JEDI-net (the
nonlinearity sees the aggregated message, not each pairwise one), so
these paths carry their own reference and accuracy story — the
latency/accuracy trade is recorded in EXPERIMENTS.md §JEDI-linear.

Two forwards share one tail:

* :func:`forward_jedi_linear`          — the O(N_o) pooled production path.
* :func:`forward_jedi_linear_edge_sum` — the same model evaluated the
  EXPENSIVE way: materialize the (N_o, N_o, H1) first-layer grid, mask
  the self-edge diagonal, sum over senders *before* the activation.
  Algebraically identical, numerically independent of the pooling
  rearrangement — the oracle that validates the O(N_o) identity (and
  the registered ``ref`` of all jedi_linear paths).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.nn import core as nn


def _cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def first_layer_split(params, cfg, x):
    """Bilinear-split first f_R layer: ``u_r``, ``u_s`` (fp32) and ``b1``.

    Same split as the fused_jedinet kernels (w1 rows [:P] receive,
    [P:] send); the projections accumulate to fp32 so the (N_o-1)-fold
    recombination below doesn't amplify low-precision products.
    """
    cdt = _cdt(cfg)
    layers = params["fr"]["layers"]
    w1 = layers[0]["w"].astype(cdt)
    b1 = layers[0]["b"].astype(jnp.float32)
    p = cfg.n_features
    x = x.astype(cdt)
    u_r = (x @ w1[:p]).astype(jnp.float32)             # (B, N_o, H1)
    u_s = (x @ w1[p:]).astype(jnp.float32)             # (B, N_o, H1)
    return u_r, u_s, b1


def _tail(params, cfg, x, h):
    """Post-aggregation network shared by both forwards: remaining f_R
    layers per NODE, C = [x ‖ Ebar], f_O, node-sum, phi_O."""
    cdt = _cdt(cfg)
    act = nn.ACTIVATIONS[cfg.activation]
    layers = params["fr"]["layers"]
    if len(layers) > 1:
        h = act(h)
    for i, lp in enumerate(layers[1:]):
        h = h.astype(cdt) @ lp["w"].astype(cdt) + lp["b"].astype(cdt)
        if i < len(layers) - 2:
            h = act(h)
    c = jnp.concatenate([x.astype(cdt), h.astype(cdt)], axis=-1)
    o = nn.mlp_apply(params["fo"], c, activation=cfg.activation,
                     compute_dtype=cdt)                # (B, N_o, D_o)
    o_sum = jnp.sum(o, axis=-2)
    logits = nn.mlp_apply(params["phi"], o_sum, activation=cfg.activation,
                          compute_dtype=cdt)
    return logits.astype(jnp.float32)


def forward_jedi_linear(params, cfg, x):
    """O(N_o) JEDI-linear forward. x: (B, N_o, P) -> logits (B, n_targets).

    The production XLA path: two per-node projections, one global sender
    pool, a per-node recombination — no edge grid anywhere.
    """
    x = x.astype(_cdt(cfg))
    u_r, u_s, b1 = first_layer_split(params, cfg, x)
    pooled = jnp.sum(u_s, axis=-2, keepdims=True)      # (B, 1, H1)
    h = (cfg.n_objects - 1) * (u_r + b1) + (pooled - u_s)
    return _tail(params, cfg, x, h)


def forward_jedi_linear_edge_sum(params, cfg, x):
    """O(N_o^2) oracle: the pooled identity expanded back into the grid.

    Materializes the full receiver x sender first-layer grid, zeroes the
    self-edge diagonal, and sums over senders BEFORE the activation —
    the summand set the O(N_o) path must reproduce, computed without the
    pooling rearrangement.  Registered as the ``ref`` of every
    jedi_linear path so the registry-parametrized numerics tests
    independently validate the identity at every bucket.
    """
    x = x.astype(_cdt(cfg))
    u_r, u_s, b1 = first_layer_split(params, cfg, x)
    grid = u_r[:, :, None, :] + u_s[:, None, :, :] + b1   # (B, N_o, N_o, H1)
    mask = 1.0 - jnp.eye(cfg.n_objects, dtype=grid.dtype)
    h = jnp.sum(grid * mask[None, :, :, None], axis=-2)   # (B, N_o, H1)
    return _tail(params, cfg, x, h)
