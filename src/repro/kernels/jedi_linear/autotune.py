"""Linear-live-set VMEM model for the JEDI-linear fused kernel.

The sender-tiled whole-network kernel's working set is
``O(block_b * N_o * block_s * H1)`` — the f_R grid slab.  JEDI-linear
has NO grid and therefore no sender axis to tile: the largest live
intermediates are the per-node projections and activations,
``O(block_b * N_o * H1)``, a factor ``block_s`` smaller.  The batch
tile grows by the same factor (weight HBM traffic amortizes over more
jets per step), and graph size stops being a VMEM constraint at all:
the per-sample set is linear in N_o, so :func:`fits_vmem` accepts
N_o=128 tracks — and far beyond — where the untiled grid model rejects
even one sample.

The shared 1D picker (:func:`repro.kernels.autotune.pick_block_b`)
consumes this model directly; :func:`pick_block_b_linear` is the
one-call convenience mirroring ``fused_jedinet.autotune.pick_block_b_s``
minus the sender knob.
"""

from __future__ import annotations

# Re-exported so kernel wrappers and tests have one import surface.
from repro.kernels.autotune import (  # noqa: F401
    VMEM_BUDGET_BYTES,
    _SUBLANE,
    effective_budget,
    mlp_widths,
    pad_batch,
    padded_batch,
    pick_block_b,
    weight_vmem_bytes,
)
from repro.kernels.fused_jedinet.autotune import fits_vmem  # noqa: F401


def linear_forward_bytes_per_sample(n_objects: int, n_features: int,
                                    fr_widths: list[int],
                                    fo_widths: list[int],
                                    phi_widths: list[int],
                                    acc_bytes: int = 4) -> int:
    """Per-jet VMEM working set of the JEDI-linear whole-network kernel.

    Live at any instant: the two first-layer projections u_r / u_s
    (each (N_o, H1) fp32), the (1, H1) sender pool, the per-NODE f_R
    activations (the widest (N_o, width) tensor — no edge grid), the x
    tile, the Ebar result, C = [x ‖ Ebar] and the f_O / phi_O
    activations.  Every term is linear in N_o — the whole point.
    """
    n_o = n_objects
    h1 = fr_widths[0]
    u_proj = 2 * n_o * h1
    pooled = h1
    fr_acts = n_o * max(fr_widths + [_SUBLANE])
    x_tile = n_o * n_features
    ebar = n_o * fr_widths[-1]
    c_tile = n_o * (n_features + fr_widths[-1])
    fo_acts = n_o * max(fo_widths + [_SUBLANE])
    phi_acts = max(phi_widths + [_SUBLANE])
    return (u_proj + pooled + fr_acts + x_tile + ebar + c_tile
            + fo_acts + phi_acts) * acc_bytes


def modeled_residency(cfg, params, batch: int, *,
                      block_b: int | None = None,
                      budget_bytes: int = VMEM_BUDGET_BYTES) -> dict:
    """The tiling decision :func:`ops.jedi_linear_forward_full` will make
    for ``batch`` samples, as data — the modeled-residency introspection
    hook the kernel-contract auditor (``repro.analysis.kernel_audit``)
    cross-checks against the traced ``pallas_call``.  Mirrors the
    wrapper's tuner invocation exactly; same contract as
    ``fused_jedinet.autotune.modeled_residency``."""
    fr_w = mlp_widths(params["fr"])
    fo_w = mlp_widths(params["fo"])
    phi_w = mlp_widths(params["phi"])
    per = linear_forward_bytes_per_sample(
        cfg.n_objects, cfg.n_features, fr_w, fo_w, phi_w)
    reserved = weight_vmem_bytes(params, cfg.compute_dtype)
    budget = effective_budget(budget_bytes, reserved)
    if block_b is None:
        block_b = pick_block_b(batch, per, budget)
    return {
        "kernel": "jedi_linear.full",
        "block_b": int(block_b),
        "block_s": None,
        "grid": (padded_batch(batch, block_b) // block_b,),
        "per_sample_bytes": int(per),
        "reserved_bytes": int(reserved),
        "effective_budget": int(budget),
        "weight_residency_bytes": int(reserved),
        "fits": fits_vmem(per, budget),
    }


def pick_block_b_linear(batch: int, n_objects: int, n_features: int,
                        fr_widths: list[int], fo_widths: list[int],
                        phi_widths: list[int],
                        budget_bytes: int = VMEM_BUDGET_BYTES,
                        reserved_bytes: int = 0) -> int:
    """Batch tile for the JEDI-linear kernel under the linear live set.

    The 1D analogue of ``fused_jedinet.autotune.pick_block_b_s``: same
    budget/reservation policy (``effective_budget``), no sender axis to
    search — the linear model leaves only the batch knob.
    """
    per = linear_forward_bytes_per_sample(
        n_objects, n_features, fr_widths, fo_widths, phi_widths)
    return pick_block_b(batch, per,
                        effective_budget(budget_bytes, reserved_bytes))
