"""Jit'd public wrapper for the fused JEDI-linear kernel.

:func:`jedi_linear_forward_full` — the whole x -> logits pipeline in one
Pallas kernel per batch tile (``linear_kernel.py``), with the batch tile
chosen from the LINEAR live-set model (``autotune.py``): no sender axis
exists, so the only tiling knob is ``block_b`` and the per-sample
working set is O(N_o * H1).  int8-quantized params (layers carrying
``"w_scale"``, see ``core/int8_path.py``) are detected here and served
with in-kernel dequantization, reusing the fused_jedinet scale plumbing
verbatim — w1's split halves share w1's per-tensor scale.

Non-divisible batches PAD to the next tile multiple instead of
degrading the tile (``autotune.pad_batch``), same contract as the
fused_jedinet wrappers: a prime batch keeps its VMEM-optimal tile.
The MXU compute dtype is ``cfg.compute_dtype``; accumulation, the
sender pool and the node-sum stay fp32.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_jedinet import full_kernel as FK
from repro.kernels.fused_jedinet import kernel as K
from repro.kernels.fused_jedinet.ops import is_quantized_params
from repro.kernels.jedi_linear import autotune
from repro.kernels.jedi_linear import linear_kernel as LK


@partial(jax.jit, static_argnames=("cfg", "interpret", "block_b"))
def jedi_linear_forward_full(params, cfg, x, *, interpret: bool = False,
                             block_b: int | None = None):
    """Fused JEDI-linear forward. x: (B, N_o, P) -> logits (B, n_targets).

    ``params`` may be raw fp32/bf16 MLPs or int8-quantized ones
    (``quantize_params_int8``); quantized layers keep their int8 weights
    all the way into VMEM.  ``block_b`` defaults to the linear-model
    autotuner; pass it explicitly to pin the tile (tests).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    quantized = is_quantized_params(params)
    fr = K.split_first_layer(params["fr"], cfg.n_features, dtype=cdt)
    fr_arrays = [fr[0], fr[1], fr[2], *fr[3]]
    fo_arrays = FK.flatten_mlp(params["fo"], cdt)
    phi_arrays = FK.flatten_mlp(params["phi"], cdt)
    scales = None
    if quantized:
        s_fr = FK.mlp_scales(params["fr"])
        # w1 splits into (w1r, w1s): both halves share w1's tensor scale
        scales = [s_fr[0], s_fr[0], *s_fr[1:],
                  *FK.mlp_scales(params["fo"]), *FK.mlp_scales(params["phi"])]

    if block_b is None:
        block_b = autotune.pick_block_b_linear(
            x.shape[0], cfg.n_objects, cfg.n_features,
            autotune.mlp_widths(params["fr"]),
            autotune.mlp_widths(params["fo"]),
            autotune.mlp_widths(params["phi"]),
            reserved_bytes=autotune.weight_vmem_bytes(
                params, cfg.compute_dtype))
    bsz = x.shape[0]
    xp = autotune.pad_batch(x.astype(cdt), block_b)
    out = LK.jedi_linear_kernel_call(
        xp, fr_arrays, fo_arrays, phi_arrays,
        activation=cfg.activation, n_targets=cfg.n_targets,
        block_b=block_b, scales=scales, interpret=interpret)
    return out[:bsz]
