"""Pallas TPU kernel: fused JEDI-linear forward (x -> logits, O(N_o)).

The whole-network JEDI-net kernel (``fused_jedinet/full_kernel.py``)
must materialize a slab of the receiver x sender f_R grid and therefore
grids over (batch, sender) tiles with a cross-step VMEM accumulator.
JEDI-linear has no grid: the linear first f_R layer commutes with the
sender sum (see ``ref.py``), so one program instance owns a batch tile
and computes

    u_r = x @ W_r,  u_s = x @ W_s            (per-node projections)
    pooled = sum_j u_s[j]                    (ONE global pool)
    Ebar1_i = (N_o-1)(u_r_i + b1) + (pooled - u_s_i)
        -> remaining f_R layers PER NODE -> C = [x ‖ Ebar]
        -> f_O -> node-sum -> phi_O -> logits

entirely in VMEM, in one grid step — no sender loop, no scratch
accumulator, no mask.  The live set is O(block_b * N_o * H1) (the
linear model in ``autotune.py``), so batch tiles grow ~``block_s``-fold
over the sender-tiled kernel and N_o stops constraining VMEM at all.

Every matmul goes through the shared ``_mmq`` helper: operands cast to
the compute dtype, fp32 accumulation via ``preferred_element_type``,
and — for int8 weights (``core/int8_path.py``) — the per-tensor dequant
scale folded into the ACCUMULATED fp32 result, so quantized weights
travel HBM -> VMEM at 1 byte/element exactly as in the fused_jedinet
kernels.  The (N_o-1)-fold recombination and both reductions (sender
pool, node-sum) stay fp32.

Grid: ``(batch tiles,)``; weights and scales broadcast to every step.
``block_b`` comes from the linear working-set model via the shared
picker (``autotune.pick_block_b_linear``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_jedinet.full_kernel import _is_int, _mmq
from repro.nn.core import ACTIVATIONS


def _linear_forward_kernel(x_ref, *rest_refs, activation: str,
                           n_fr: int, n_fo: int, n_phi: int, n_o: int,
                           quantized: bool, compute_dtype):
    """rest_refs = [scales?] + [w1r, w1s, b1, (fr w/b)*, (fo w/b)*,
    (phi w/b)*] + [out_ref].

    ``x_ref`` — (block_b, N_o, P): the batch tile, read once; both
    projections and the pool are computed from this resident block.
    Weight refs arrive pre-cast to the compute dtype (or int8 when
    ``quantized``); biases are fp32.  Scale-index bookkeeping matches
    the fused_jedinet kernel: w1's split halves share w1's scale.
    """
    out_ref = rest_refs[-1]
    wref = list(rest_refs[:-1])
    if quantized:
        scales_ref, wref = wref[0], wref[1:]

        def s(k):
            return scales_ref[0, k]
    else:
        def s(k):
            return None
    act = ACTIVATIONS[activation]

    w1r, w1s, b1 = wref[0], wref[1], wref[2]
    fr_rest = wref[3:3 + 2 * (n_fr - 1)]
    fo_w = wref[3 + 2 * (n_fr - 1):3 + 2 * (n_fr - 1) + 2 * n_fo]
    phi_w = wref[3 + 2 * (n_fr - 1) + 2 * n_fo:]
    # scale index of each weight tensor, in ref order (biases carry none)
    k_fr = list(range(n_fr + 1))                       # w1r, w1s, w2..
    k_fo = [n_fr + 1 + i for i in range(n_fo)]
    k_phi = [n_fr + 1 + n_fo + i for i in range(n_phi)]

    x = x_ref[...]                                     # (bb, N_o, P) cdt

    # --- f_R layer 1, pooled: two per-node projections, one global
    # sender pool, per-node recombination.  All fp32 after _mmq.
    u_r = _mmq(x, w1r, s(k_fr[0]), compute_dtype)      # (bb, N_o, H1)
    u_s = _mmq(x, w1s, s(k_fr[1]), compute_dtype)      # (bb, N_o, H1)
    pooled = jnp.sum(u_s, axis=1, keepdims=True)       # (bb, 1, H1)
    h = (n_o - 1) * (u_r + b1[...]) + (pooled - u_s)
    if n_fr > 1:                                       # f_R output is linear
        h = act(h)

    # --- remaining f_R layers run per NODE: (bb, N_o, width), no grid
    for li in range(n_fr - 1):
        h = _mmq(h, fr_rest[2 * li], s(k_fr[2 + li]), compute_dtype) \
            + fr_rest[2 * li + 1][...]
        if li < n_fr - 2:
            h = act(h)

    # --- C = [x ‖ Ebar], f_O, node-sum, phi_O — all in the same step
    h = jnp.concatenate([x.astype(jnp.float32), h], axis=-1)
    for li in range(n_fo):
        h_ = _mmq(h, fo_w[2 * li], s(k_fo[li]), compute_dtype) \
            + fo_w[2 * li + 1][...]
        h = act(h_) if li < n_fo - 1 else h_           # (bb, N_o, D_o)
    h = jnp.sum(h, axis=1)                             # (bb, D_o) fp32
    for li in range(n_phi):
        h_ = _mmq(h, phi_w[2 * li], s(k_phi[li]), compute_dtype) \
            + phi_w[2 * li + 1][...]
        h = act(h_) if li < n_phi - 1 else h_
    out_ref[...] = h.astype(out_ref.dtype)             # (bb, n_targets)


def jedi_linear_kernel_call(x, fr_arrays, fo_arrays, phi_arrays, *,
                            activation: str, n_targets: int, block_b: int,
                            scales=None, interpret: bool = False):
    """x: (B, N_o, P) compute-dtype -> logits (B, n_targets) fp32.

    ``B % block_b == 0`` (callers pad via autotune.pad_batch).
    ``fr_arrays = [w1r, w1s, b1, w2, b2, ...]`` from split_first_layer.
    ``scales`` — fp32 vector of per-weight-tensor dequant scales, in
    weight order [w1r, w1s, w2.., fo.., phi..], required iff any weight
    array is an integer dtype (in-kernel int8 dequant).
    """
    bsz, n_o, p = x.shape
    n_fr = 1 + (len(fr_arrays) - 3) // 2
    n_fo = len(fo_arrays) // 2
    n_phi = len(phi_arrays) // 2
    weights = [*fr_arrays, *fo_arrays, *phi_arrays]
    quantized = any(_is_int(w) for w in weights)
    compute_dtype = x.dtype

    if bsz % block_b != 0:
        from repro.kernels.jedi_linear import autotune as jl_autotune
        fr_w = [int(w.shape[-1]) for w in fr_arrays[0:1] + fr_arrays[3::2]]
        fo_w = [int(w.shape[-1]) for w in fo_arrays[0::2]]
        phi_w = [int(w.shape[-1]) for w in phi_arrays[0::2]]
        modeled = jl_autotune.linear_forward_bytes_per_sample(
            n_o, p, fr_w, fo_w, phi_w)
        raise ValueError(
            f"batch {bsz} is not a multiple of the batch tile: autotuned "
            f"block_b={block_b} at modeled {modeled} VMEM bytes/sample — "
            f"pad the batch with autotune.pad_batch(x, {block_b}) (kernel "
            f"wrappers do this automatically)")
    if quantized:
        n_w = len(weights) // 2 + 1                  # +1: w1 split in two
        if scales is None:
            raise ValueError(
                "int8 weight arrays need their dequant scales: pass "
                "scales=[s_w1r, s_w1s, s_w2, ...] (one per weight tensor)")
        scales = jnp.asarray(scales, jnp.float32).reshape(1, -1)
        if scales.shape[1] != n_w:
            raise ValueError(
                f"got {scales.shape[1]} scales for {n_w} weight tensors")

    grid = (bsz // block_b,)

    def wmap(ndim):
        def m(i):
            return (0,) * ndim
        return m

    in_specs = [pl.BlockSpec((block_b, n_o, p), lambda i: (i, 0, 0))]
    operands = [x]
    if quantized:
        in_specs.append(pl.BlockSpec(scales.shape, wmap(scales.ndim)))
        operands.append(scales)
    for w in weights:
        in_specs.append(pl.BlockSpec(w.shape, wmap(w.ndim)))
    operands.extend(weights)

    kernel = functools.partial(
        _linear_forward_kernel, activation=activation, n_fr=n_fr, n_fo=n_fo,
        n_phi=n_phi, n_o=n_o, quantized=quantized,
        compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, n_targets), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n_targets), jnp.float32),
        interpret=interpret,
    )(*operands)
