"""Logical-axis sharding: one rules table maps model-semantic axes to mesh axes.

Model code annotates activations with *logical* axis names
(``constrain(h, "batch", "seq", None)``); the launcher installs an
``axis_rules`` context binding those names to physical mesh axes for the
active mesh (single-pod ``(data, model)`` or multi-pod ``(pod, data,
model)``).  Outside a context every annotation is a no-op, so unit tests and
CPU examples run unsharded with the exact same model code.

Parameter sharding is path-regex based (``PARAM_RULES``): a handful of rules
per family cover embeddings, attention, MLP, MoE experts, GNN and recsys
tables.  Weights are sharded over BOTH mesh axes where possible
(tensor-parallel over ``model`` + FSDP/ZeRO-3 over ``data``) so the 480B
Arctic checkpoint fits 256 x 16 GiB chips; XLA inserts the corresponding
all-gathers / reduce-scatters.

Non-divisible cases (e.g. 56 heads over 16-way ``model``) are allowed: the
SPMD partitioner pads. The roofline analysis charges that padding honestly.
"""

from __future__ import annotations

import contextlib
import inspect
import re
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.tree import path_map

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# The replication-check kwarg was renamed check_rep -> check_vma.
_SHARD_MAP_CHECK_KW = (
    "check_rep" if "check_rep" in inspect.signature(_shard_map).parameters
    else "check_vma")


def shard_map_compat(fn, mesh, *, in_specs, out_specs, check: bool = False):
    """``shard_map`` across jax versions: import location + check kwarg.

    ``check=False`` (the default) disables the replication/VMA check —
    required for bodies containing ``pallas_call`` (no replication rule)
    or manual collectives the checker cannot type.
    """
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_SHARD_MAP_CHECK_KW: check})


_CTX = threading.local()


# Logical axis -> tuple of mesh axes that shard it (filtered by mesh).
DEFAULT_RULES = {
    # activations
    "batch": ("pod", "data"),
    "seq": ("model",),                # sequence-parallel residual stream
    "tokens": ("pod", "data", "model"),  # flattened (batch*seq) token axis
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qlen": (),
    "kvlen": ("model",),       # seq-sharded KV cache when kv_heads < model
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "classes": (),
    # MoE
    "expert": ("data",),
    # NB: sharding expert_slot over `model` was tried in §Perf cell B and
    # measured neutral (227 vs 231 GB/device collectives) — the dispatch
    # scatter still all-gathers its payload; see EXPERIMENTS.md §Perf.
    "expert_slot": (),
    # graphs: node/edge sets are sharded over the full chip set
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
    "graph_feat": (),
    # recsys
    "table_rows": ("pod", "data", "model"),
    "candidates": ("pod", "data", "model"),
    # weights
    "fsdp": ("data",),
    "w_model": ("model",),
    "replicated": (),
    # pipeline stage axis (only bound when PP over pods is enabled)
    "stage": ("pod",),
}


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[dict] = None):
    """Install a (mesh, logical-rules) context for `constrain`."""
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, dict(DEFAULT_RULES, **(rules or {})))
    try:
        yield
    finally:
        _CTX.state = prev


def current_mesh() -> Optional[Mesh]:
    st = getattr(_CTX, "state", None)
    return st[0] if st else None


def _filter_axes(axes, mesh: Mesh):
    """Keep only axes present in the mesh (e.g. drop 'pod' on single-pod)."""
    present = tuple(a for a in axes if a in mesh.axis_names)
    if len(present) == 0:
        return None
    if len(present) == 1:
        return present[0]
    return present


def logical_to_spec(logical_axes, mesh: Mesh, rules: dict) -> P:
    """('batch', None, 'embed') -> PartitionSpec for this mesh."""
    spec = []
    for name in logical_axes:
        if name is None:
            spec.append(None)
            continue
        axes = rules.get(name)
        if axes is None:
            raise KeyError(f"unknown logical axis {name!r}")
        spec.append(_filter_axes(axes, mesh))
    return P(*spec)


def constrain(x, *logical_axes):
    """with_sharding_constraint via logical axes; no-op without a context."""
    st = getattr(_CTX, "state", None)
    if st is None:
        return x
    mesh, rules = st
    spec = logical_to_spec(logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-regex -> logical axes per dimension).
#
# Paths look like "layers/attn/wq/w" (scan-stacked layers carry a leading
# n_layers dim, which is always unsharded: the regex rules below give the
# *trailing* dims and we left-pad with None).
# ---------------------------------------------------------------------------

PARAM_RULES: list[tuple[str, tuple]] = [
    # --- LM ---
    (r".*embed/w$", ("vocab", "fsdp")),
    (r".*lm_head/w$", ("fsdp", "vocab")),
    (r".*(wq|wkv_q)/w$", ("fsdp", "w_model")),
    (r".*wk/w$", ("fsdp", "w_model")),
    (r".*wv/w$", ("fsdp", "w_model")),
    (r".*wo/w$", ("w_model", "fsdp")),
    (r".*(w_gate|w_in)/w$", ("fsdp", "w_model")),
    (r".*w_out/w$", ("w_model", "fsdp")),
    (r".*router/w$", ("fsdp", None)),
    # MoE experts: (E, d, ff) / (E, ff, d)
    (r".*experts/(w_gate|w_in)$", ("expert", None, "w_model")),
    (r".*experts/w_out$", ("expert", "w_model", None)),
    # --- GNN --- weights are small: shard the fan-in over data (FSDP) only.
    (r".*gnn.*/w$", ("fsdp", None)),
    # --- recsys ---
    (r".*tables/rows$", ("table_rows", None)),
    (r".*field_bias/rows$", ("table_rows",)),
]


def _divisible_entry(dim_size: int, entry, mesh: Mesh):
    """Trim a spec entry (axis | tuple | None) to the longest prefix of mesh
    axes whose product divides dim_size.

    jit in_shardings (unlike with_sharding_constraint) require exact
    divisibility; non-dividing dims fall back to fewer axes / replication.
    The roofline then charges the replication honestly.
    """
    if entry is None or dim_size is None:
        return entry
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    keep = []
    prod = 1
    for a in axes:
        sz = mesh.shape[a]
        if dim_size % (prod * sz) == 0:
            keep.append(a)
            prod *= sz
        else:
            break
    if not keep:
        return None
    return keep[0] if len(keep) == 1 else tuple(keep)


def divisible_spec(spec: P, shape, mesh: Mesh) -> P:
    t = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    return P(*[_divisible_entry(int(d), e, mesh)
               for d, e in zip(shape, t)])


def _spec_for_path(path: str, ndim: int, mesh: Mesh, rules: dict,
                   shape=None) -> P:
    for pat, logical in PARAM_RULES:
        if re.match(pat, path):
            pad = ndim - len(logical)
            axes = (None,) * pad + tuple(logical)
            spec = logical_to_spec(axes, mesh, rules)
            if shape is not None:
                spec = divisible_spec(spec, shape, mesh)
            return spec
    return P()  # replicate (norms, biases, small heads)


def param_shardings(params, mesh: Mesh, rules: Optional[dict] = None):
    """Pytree of NamedShardings for a param pytree, via PARAM_RULES."""
    rules = dict(DEFAULT_RULES, **(rules or {}))

    def _one(path, leaf):
        shape = tuple(leaf.shape)
        return NamedSharding(
            mesh, _spec_for_path(path, len(shape), mesh, rules, shape))

    return path_map(_one, params)


def _padded_spec(spec: P, ndim: int) -> tuple:
    t = tuple(spec)
    return t + (None,) * (ndim - len(t))


def train_state_shardings(state, mesh: Mesh, rules: Optional[dict] = None):
    """Shardings for a full trainer state {params, opt, step}.

    Optimizer moments follow their parameter's sharding; Adafactor's
    factored accumulators drop the reduced axis from the param spec
    (r = mean over last dim -> spec[:-1]; c = mean over second-to-last ->
    spec[:-2] + spec[-1:]), so the big per-expert accumulators stay
    sharded exactly like their weights.
    """
    rules_d = dict(DEFAULT_RULES, **(rules or {}))
    params = state["params"]

    flat_spec: dict = {}

    def _collect(path, leaf):
        shape = tuple(leaf.shape)
        flat_spec[path] = _padded_spec(
            _spec_for_path(path, len(shape), mesh, rules_d, shape),
            len(shape))
        return leaf

    path_map(_collect, params)

    p_sh = path_map(
        lambda p, l: NamedSharding(mesh, P(*flat_spec[p])), params)

    def _opt_leaf(path, leaf):
        parts = path.split("/")
        head, rest = parts[0], parts[1:]
        if head in ("m", "v", "mu"):
            key = "/".join(rest)
            spec = flat_spec.get(key)
            return NamedSharding(mesh, P(*spec) if spec else P())
        if head == "acc":
            kind = rest[-1]
            key = "/".join(rest[:-1])
            spec = flat_spec.get(key)
            if spec is None:
                return NamedSharding(mesh, P())
            if kind == "v":
                return NamedSharding(mesh, P(*spec))
            if kind == "r":
                return NamedSharding(mesh, P(*spec[:-1]))
            if kind == "c":
                return NamedSharding(mesh, P(*spec[:-2], spec[-1]))
        return NamedSharding(mesh, P())

    opt_sh = path_map(_opt_leaf, state["opt"])
    return {"params": p_sh, "opt": opt_sh,
            "step": NamedSharding(mesh, P())}


def kv_cache_shardings(cache, mesh: Mesh, rules: Optional[dict] = None):
    """Shardings for a decode KV cache {k, v, slot_pos, pos}.

    Preferred: shard the kv-head axis over `model` (head parallelism).
    When kv_heads doesn't divide the model axis (GQA with few KV heads,
    e.g. arctic kv=8 on a 16-way model axis), fall back to sharding the
    cache SEQUENCE axis over `model` instead — attention over a
    seq-sharded cache becomes a distributed flash-decode (partial softmax
    + all-reduce), which SPMD partitioning emits automatically.
    """
    rules_d = dict(DEFAULT_RULES, **(rules or {}))
    kshape = tuple(cache["k"].shape)      # (L, B, S, Hkv, D)
    model_sz = 1
    for a in rules_d["kv_heads"]:
        if a in mesh.axis_names:
            model_sz *= mesh.shape[a]
    heads_divide = kshape[3] % max(model_sz, 1) == 0

    def spec(shape, *axes):
        s = logical_to_spec(axes, mesh, rules_d)
        return NamedSharding(mesh, divisible_spec(s, shape, mesh))

    if heads_divide:
        kv_axes = (None, "batch", None, "kv_heads", None)
    else:
        kv_axes = (None, "batch", "kvlen", None, None)
    return {
        "k": spec(kshape, *kv_axes),
        "v": spec(kshape, *kv_axes),
        "slot_pos": spec(tuple(cache["slot_pos"].shape), "batch", None),
        "pos": spec(tuple(cache["pos"].shape), "batch"),
    }


def batch_shardings(batch, mesh: Mesh, axes_map: dict,
                    rules: Optional[dict] = None):
    """Shardings for an input batch dict via a {key: logical axes} map.

    Divisibility-aware: dims that don't divide their mesh axes keep only a
    dividing prefix (or replicate) so jit in_shardings always validate.
    """
    rules_d = dict(DEFAULT_RULES, **(rules or {}))
    out = {}
    for k, leaf in batch.items():
        axes = axes_map.get(k)
        if axes is None:
            out[k] = NamedSharding(mesh, P())
        else:
            spec = logical_to_spec(axes, mesh, rules_d)
            out[k] = NamedSharding(
                mesh, divisible_spec(spec, tuple(leaf.shape), mesh))
    return out
