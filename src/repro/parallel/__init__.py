from repro.parallel.sharding import (
    axis_rules,
    constrain,
    current_mesh,
    logical_to_spec,
    param_shardings,
    DEFAULT_RULES,
)

__all__ = [
    "axis_rules", "constrain", "current_mesh", "logical_to_spec",
    "param_shardings", "DEFAULT_RULES",
]
