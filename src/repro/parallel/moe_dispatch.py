"""shard_map MoE dispatch: the §Perf cell B "b3" design, validated.

Auto-SPMD resolves the token->expert-buffer scatter of `models/moe.py`
by all-gathering the full dispatch payload (54.8 GB/device/layer on the
moonshot train cell).  The communication-optimal dispatch is a single
all-to-all, which requires manual SPMD (shard_map):

  per data shard (T_loc tokens):
    1. route locally: stable-argsort the (T_loc * k) assignments by
       expert, position each within a fixed per-(shard, expert) capacity
       C_loc (drop beyond — same dropping semantics as the global path,
       applied per shard);
    2. build the local send buffer (E, C_loc, d);
    3. `lax.all_to_all` over the expert axis -> each shard receives
       (E/S, S * C_loc, d): ITS experts' tokens from every shard;
    4. expert FFN on local experts;
    5. reverse all_to_all, local combine with the gate weights.

  Traffic per step = send-buffer bytes = E * C_loc * d, i.e. the payload
  itself (~T*k*d/S per shard), vs the payload *all-gathered S times* in
  the auto-SPMD path — the ~500x in EXPERIMENTS.md §Perf cell B.

This module is the validated building block (tests/test_multidevice.py
exercises it on an 8-device mesh against the global-dispatch reference);
wiring it into the scan+remat transformer train step is left as the
documented next step.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.nn import core as nn


def local_route(x_loc, expert_ids, gate_vals, n_experts: int, cap: int):
    """Per-shard routing. x_loc: (T_loc, d); expert_ids/gate_vals: (T_loc, k).

    Returns (send (E, cap, d), slot (T_loc*k,) flat slot per assignment
    with E*cap = dropped).
    """
    t, d = x_loc.shape
    k = expert_ids.shape[1]
    flat_e = expert_ids.reshape(-1)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = pos < cap
    slot_sorted = jnp.where(keep, sorted_e * cap + pos, n_experts * cap)
    # slot per ORIGINAL assignment index
    slot = jnp.zeros((t * k,), jnp.int32).at[sort_idx].set(slot_sorted)
    token_of_sorted = (sort_idx // k).astype(jnp.int32)
    send = jnp.zeros((n_experts * cap + 1, d), x_loc.dtype)
    send = send.at[slot_sorted].set(x_loc[token_of_sorted], mode="drop")
    return send[:-1].reshape(n_experts, cap, d), slot


def a2a_moe_shard(x_loc, params, n_experts: int, cap: int, *,
                  axis_name: str, n_shards: int, top_k: int,
                  activation: str = "silu"):
    """One shard's MoE forward (call inside shard_map over `axis_name`).

    x_loc: (T_loc, d).  params: same pytree as models/moe.init_moe.
    Returns (T_loc, d).
    """
    t, d = x_loc.shape
    e_loc = n_experts // n_shards
    act = nn.ACTIVATIONS[activation]

    logits = (x_loc @ params["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    send, slot = local_route(x_loc, expert_ids, gate_vals, n_experts, cap)

    # all-to-all: (E, cap, d) -> (E/S, S*cap, d); shard s receives the
    # buffers destined to ITS experts from every shard.
    recv = jax.lax.all_to_all(send.reshape(n_shards, e_loc, cap, d),
                              axis_name, split_axis=0, concat_axis=0)
    h = recv.reshape(e_loc, n_shards * cap, d)

    # local experts' weights (each shard owns E/S experts)
    idx = jax.lax.axis_index(axis_name)
    wg = jax.lax.dynamic_slice_in_dim(params["experts"]["w_gate"],
                                      idx * e_loc, e_loc, 0)
    wi = jax.lax.dynamic_slice_in_dim(params["experts"]["w_in"],
                                      idx * e_loc, e_loc, 0)
    wo = jax.lax.dynamic_slice_in_dim(params["experts"]["w_out"],
                                      idx * e_loc, e_loc, 0)
    y = jnp.einsum("ecf,efd->ecd",
                   act(jnp.einsum("ecd,edf->ecf", h, wg))
                   * jnp.einsum("ecd,edf->ecf", h, wi), wo)

    # reverse all-to-all back to the sending shards
    back = jax.lax.all_to_all(
        y.reshape(e_loc, n_shards, cap, d).swapaxes(0, 1),
        axis_name, split_axis=0, concat_axis=0)        # (1*, E, cap, d)
    y_local = back.reshape(n_experts * cap, d)
    y_flat = jnp.concatenate([y_local, jnp.zeros((1, d), y_local.dtype)], 0)

    per_assign = y_flat[slot]                          # (T_loc*k, d)
    gates = gate_vals.reshape(-1)[:, None].astype(per_assign.dtype)
    out = jnp.sum((per_assign * gates).reshape(t, top_k, d), axis=1)
    return out


def a2a_moe(x, params, moe_cfg, mesh, axis_name: str = "data"):
    """Convenience wrapper: shard_map the dispatch over `axis_name`.

    x: (T, d) global; tokens must divide the axis size.
    Capacity matches models/moe.capacity in expectation (per-shard).
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.moe import capacity
    from repro.parallel.sharding import shard_map_compat

    n_shards = mesh.shape[axis_name]
    t = x.shape[0]
    cap = capacity(t // n_shards, moe_cfg)

    fn = partial(a2a_moe_shard, n_experts=moe_cfg.n_experts, cap=cap,
                 axis_name=axis_name, n_shards=n_shards,
                 top_k=moe_cfg.top_k)
    return shard_map_compat(
        fn, mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(axis_name))(x, params)
