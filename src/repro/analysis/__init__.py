"""Static-analysis subsystem: lint rules + kernel-contract auditor.

Two engines, one finding type, one CLI (``python -m repro.analysis``):

* :mod:`repro.analysis.lint` — AST rules over the tracked tree
  (thin-CLI shape, retired names, pallas containment, wall-clock
  seams, registration-site declarations);
* :mod:`repro.analysis.kernel_audit` — traces every registered Pallas
  path at each bucket of its ladder and cross-checks grid/BlockSpec/
  scratch/dtype reality against the autotuner bytes models, without
  executing a kernel.

Per-rule allowlists live in ``analysis.toml`` at the repo root.
"""

from repro.analysis.config import AnalysisConfig
from repro.analysis.findings import Finding
from repro.analysis.lint import LintContext, run_lint

__all__ = ["AnalysisConfig", "Finding", "LintContext", "run_lint"]
