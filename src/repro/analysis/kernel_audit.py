"""Kernel-contract auditor: the static half of the autotuner story.

LL-GNN's co-design flow works because hardware constraints — on-chip
residency, accumulator precision — are checked BEFORE synthesis.  This
module is the jax_pallas analogue: for every registered Pallas path it
traces the forward at each rung of the path's own bucket ladder with
``jax.make_jaxpr`` (abstract shapes only — no kernel ever executes),
digs the ``pallas_call`` equations out of the jaxpr, and cross-checks
what the kernel ACTUALLY asks the compiler for against what the
autotuner bytes model CLAIMS it asks for:

* **grid/tile agreement** — the traced grid and the x-operand block
  shape must equal the :attr:`PathSpec.residency_model` hook's decision
  exactly (the hook mirrors the wrapper's tuner invocation, so drift
  here means the hand-written bytes model and the kernel BlockSpecs
  disagree — the silent-drift bug class this auditor exists for);
* **weight residency** — the summed BlockSpec bytes of the non-x
  inputs must match the model's ``weight_residency_bytes`` within
  ``DRIFT_TOLERANCE`` (5%).  This doubles as the int8 proof: weights
  shipped as fp32 instead of int8 would show 4x drift;
* **fp32 accumulation** — every ``dot_general`` inside the kernel, every
  VMEM scratch allocation, and every kernel output must be float32;
* **int8 operand discipline** — quantized paths ship integer dtypes
  into VMEM (every non-x matrix input is integer), carry exactly one
  fp32 scale vector, and fold each scale exactly once (the scales ref
  is read exactly once per integer tensor);
* **intermediate bound** — the largest single tensor materialized inside
  the kernel, per sample, must not exceed the model's
  ``per_sample_bytes`` (within tolerance): the model must be an upper
  bound on any one live tensor or ``fits_vmem`` acceptance is a lie;
* **ladder/budget closure** — every rung the path's bucket ladder hands
  to serving must fit ``effective_budget`` under the model
  (``block_b * per_sample_bytes <= effective_budget`` and ``fits``
  true), closing the gap where a hand-pinned bucket exceeds the weight
  reservation;
* **containment** — non-Pallas paths trace to ZERO pallas_calls, and
  Pallas paths to at least one (the ``pallas=True`` tag is load-bearing
  for serving's interpret-mode fallback, so it must be true).

Findings use ``rule="audit-<check>"`` ids so the same ``analysis.toml``
allowlist machinery scopes sanctioned exceptions.
"""

from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding

#: Relative VMEM-model drift that fails the audit.
DRIFT_TOLERANCE = 0.05


# ---------------------------------------------------------------------------
# Jaxpr spelunking.
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    """All equations in ``jaxpr`` and every jaxpr nested in its params
    (pjit bodies, scan carries, pallas kernel jaxprs...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from _iter_eqns(sub)


def _sub_jaxprs(val):
    import jax.core as jcore
    closed = getattr(jcore, "ClosedJaxpr", ())
    if isinstance(val, closed):
        yield val.jaxpr
    elif isinstance(val, jcore.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from _sub_jaxprs(item)


def find_pallas_calls(jaxpr):
    """Every ``pallas_call`` equation reachable from ``jaxpr``."""
    return [e for e in _iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def trace_forward(spec, cfg, params, batch: int):
    """``jax.make_jaxpr`` of the path's forward at abstract shapes —
    runs the wrapper's tuner and BlockSpec construction for real, never
    the kernel body."""
    import jax
    import jax.numpy as jnp
    x = jax.ShapeDtypeStruct((batch, cfg.n_objects, cfg.n_features),
                             jnp.float32)
    return jax.make_jaxpr(lambda xv: spec.forward(params, cfg, xv))(x)


def _aval_bytes(aval) -> int:
    return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize


def _block_bytes(bm) -> int:
    shape = tuple(int(d) for d in bm.block_shape)
    return int(np.prod(shape, dtype=np.int64)) * bm.array_shape_dtype.dtype.itemsize


class TracedKernel:
    """Structured view of one traced ``pallas_call`` equation."""

    def __init__(self, eqn):
        gm = eqn.params["grid_mapping"]
        self.name = str(eqn.params.get("name_and_src_info", "pallas_call"))
        self.grid = tuple(int(g) for g in gm.grid)
        self.in_blocks = list(gm.block_mappings[:gm.num_inputs])
        self.out_blocks = list(
            gm.block_mappings[gm.num_inputs:gm.num_inputs + gm.num_outputs])
        self.num_scratch = int(gm.num_scratch_operands)
        self.kernel_jaxpr = eqn.params["jaxpr"]
        self.out_avals = list(eqn.params["out_avals"])
        invars = self.kernel_jaxpr.invars
        n_io = len(self.in_blocks) + len(self.out_blocks)
        self.scratch_avals = [v.aval for v in invars[n_io:]]
        # kernel-side refs, for read counting (scale-fold discipline)
        self.in_refs = invars[:len(self.in_blocks)]

    # x is always the kernel's first operand (repo-wide kernel idiom:
    # the batch tensor leads, weights broadcast behind it).
    @property
    def x_block(self):
        return self.in_blocks[0]

    @property
    def weight_blocks(self):
        return self.in_blocks[1:]

    def scalar_f32_read_count(self) -> int:
        """Scalar fp32 ``get``s anywhere in the kernel (cond branches
        included — ``pl.when`` tails re-bind refs, so identity-based
        attribution undercounts).  In these kernels the ONLY scalar
        fp32 ref reads are dequant-scale folds, so this count IS the
        number of scale folds."""
        import jax.numpy as jnp
        return sum(1 for e in _iter_eqns(self.kernel_jaxpr)
                   if e.primitive.name == "get"
                   and e.outvars[0].aval.shape == ()
                   and e.outvars[0].aval.dtype == jnp.float32)


# ---------------------------------------------------------------------------
# Per-check helpers (each returns a list of Findings).
# ---------------------------------------------------------------------------

def _loc(spec, batch: int | None = None) -> str:
    return (f"path={spec.name}" if batch is None
            else f"path={spec.name} bucket={batch}")


def _drift(actual: float, modeled: float) -> float:
    if modeled == 0:
        return float("inf") if actual else 0.0
    return abs(actual - modeled) / modeled


def _check_tiling(spec, batch, kernels, model):
    findings = []
    grids = [k.grid for k in kernels]
    if model["grid"] is not None and tuple(model["grid"]) not in grids:
        findings.append(Finding(
            "audit-tile-mismatch", _loc(spec, batch), 0,
            f"traced pallas_call grid(s) {grids} never match the "
            f"autotuner model's grid {tuple(model['grid'])} "
            f"(block_b={model['block_b']}, block_s={model['block_s']}) — "
            "the kernel wrapper and the residency_model hook have drifted; "
            "re-mirror the tuner invocation in the autotune module"))
    for k in kernels:
        bb = int(k.x_block.block_shape[0])
        if bb != int(model["block_b"]):
            findings.append(Finding(
                "audit-tile-mismatch", _loc(spec, batch), 0,
                f"kernel {k.name}: x BlockSpec batch tile is {bb}, the "
                f"autotuner model picked block_b={model['block_b']} — "
                "BlockSpec and bytes model disagree; whichever is right, "
                "make the other match"))
    return findings


def _check_weight_residency(spec, batch, kernels, model):
    findings = []
    for k in kernels:
        traced = sum(_block_bytes(bm) for bm in k.weight_blocks)
        drift = _drift(traced, model["weight_residency_bytes"])
        if drift > DRIFT_TOLERANCE:
            findings.append(Finding(
                "audit-vmem-drift", _loc(spec, batch), 0,
                f"kernel {k.name}: traced weight-operand BlockSpecs "
                f"occupy {traced} B of VMEM but the model reserves "
                f"{model['weight_residency_bytes']} B "
                f"({drift:.0%} drift > {DRIFT_TOLERANCE:.0%}) — "
                "weight_vmem_bytes and the kernel's weight BlockSpecs "
                "have diverged (a quantized path shipping fp32 weights "
                "shows up here as ~4x drift)"))
    return findings


def _check_intermediates(spec, batch, kernels, model):
    findings = []
    per_cap = model["per_sample_bytes"] * (1 + DRIFT_TOLERANCE)
    for k in kernels:
        bb = max(1, int(k.x_block.block_shape[0]))
        largest, largest_eqn = 0, None
        for eqn in _iter_eqns(k.kernel_jaxpr):
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "shape", None) is not None:
                    b = _aval_bytes(aval)
                    if b > largest:
                        largest, largest_eqn = b, eqn.primitive.name
        per_sample = largest / bb
        if per_sample > per_cap:
            findings.append(Finding(
                "audit-vmem-drift", _loc(spec, batch), 0,
                f"kernel {k.name}: largest traced intermediate "
                f"({largest_eqn}, {largest} B / block_b={bb} -> "
                f"{per_sample:.0f} B/sample) exceeds the model's "
                f"per_sample_bytes={model['per_sample_bytes']} — the bytes "
                "model no longer upper-bounds the kernel's live set, so "
                "fits_vmem acceptance is unsound; grow the model or "
                "shrink the tensor"))
    return findings


def _check_fp32_accumulation(spec, batch, kernels):
    import jax.numpy as jnp
    findings = []
    for k in kernels:
        for aval in k.scratch_avals:
            dt = getattr(aval, "dtype", None)
            if dt is not None and dt != jnp.float32:
                findings.append(Finding(
                    "audit-accum-dtype", _loc(spec, batch), 0,
                    f"kernel {k.name}: VMEM scratch accumulator is {dt}, "
                    "must be float32 — bf16/int accumulation breaks the "
                    "declared tolerance class; allocate scratch as "
                    "jnp.float32 and cast at the edges"))
        for eqn in _iter_eqns(k.kernel_jaxpr):
            if eqn.primitive.name != "dot_general":
                continue
            out_dt = eqn.outvars[0].aval.dtype
            if out_dt != jnp.float32:
                findings.append(Finding(
                    "audit-accum-dtype", _loc(spec, batch), 0,
                    f"kernel {k.name}: dot_general accumulates in {out_dt}, "
                    "must be float32 — pass "
                    "preferred_element_type=jnp.float32 and fold scales/"
                    "casts after the accumulate"))
        for aval in k.out_avals:
            if aval.dtype != jnp.float32:
                findings.append(Finding(
                    "audit-accum-dtype", _loc(spec, batch), 0,
                    f"kernel {k.name}: kernel output is {aval.dtype}, "
                    "must be float32 — logits leave the kernel at full "
                    "precision"))
    return findings


def _check_int8_discipline(spec, batch, kernels):
    import jax.numpy as jnp
    findings = []
    for k in kernels:
        int_inputs, scale_rows = [], []
        for bm in k.weight_blocks:
            dt = bm.array_shape_dtype.dtype
            shape = bm.array_shape_dtype.shape
            if jnp.issubdtype(dt, jnp.integer):
                int_inputs.append(bm)
            elif dt == jnp.float32 and len(shape) == 2 and shape[0] == 1:
                scale_rows.append(bm)
            elif dt == jnp.float32 and len(shape) == 1:
                pass                      # biases stay fp32 by design
            else:
                findings.append(Finding(
                    "audit-int8-operands", _loc(spec, batch), 0,
                    f"kernel {k.name}: quantized path ships a "
                    f"{dt}{list(shape)} operand into VMEM — int8 paths "
                    "carry integer weight matrices, fp32 biases, and one "
                    "fp32 scale row only; quantize this tensor or fold it "
                    "into the scales"))
        if not int_inputs:
            findings.append(Finding(
                "audit-int8-operands", _loc(spec, batch), 0,
                f"kernel {k.name}: quantized path traced ZERO integer "
                "VMEM operands — the weights are being dequantized on the "
                "host, which forfeits the 4x residency win the path's "
                "weight_bytes=1 declaration claims"))
            continue
        if len(scale_rows) != 1:
            findings.append(Finding(
                "audit-int8-operands", _loc(spec, batch), 0,
                f"kernel {k.name}: expected exactly one fp32 scale row "
                f"operand, traced {len(scale_rows)} — per-tensor scales "
                "ship as a single (1, n_tensors) fp32 input"))
            continue
        n_scales = int(scale_rows[0].array_shape_dtype.shape[-1])
        reads = k.scalar_f32_read_count()
        if n_scales != len(int_inputs) or reads != len(int_inputs):
            findings.append(Finding(
                "audit-int8-operands", _loc(spec, batch), 0,
                f"kernel {k.name}: scale-fold discipline broken — "
                f"{len(int_inputs)} integer tensors, {n_scales} scales, "
                f"{reads} scale reads; each tensor's scale must fold "
                "exactly once (after the fp32 accumulate), so all three "
                "counts must agree"))
    return findings


def _check_ladder(spec, cfg, params, max_batch):
    """Satellite (f): every rung the path's bucket ladder hands to
    serving must fit effective_budget under the model."""
    findings = []
    ladder = spec.bucket_ladder(cfg, params, max_batch)
    if not ladder:
        findings.append(Finding(
            "audit-ladder-budget", _loc(spec), 0,
            "bucket_ladder is empty — even one sample does not fit the "
            "VMEM budget after the weight reservation; the path cannot "
            "serve at all"))
        return findings, ladder
    for rung in ladder:
        model = spec.residency_model(cfg, params, rung)
        tile = model["block_b"] * model["per_sample_bytes"]
        if not model["fits"] or tile > model["effective_budget"]:
            findings.append(Finding(
                "audit-ladder-budget", _loc(spec, rung), 0,
                f"ladder rung {rung} does not fit: block tile "
                f"{model['block_b']} x {model['per_sample_bytes']} B = "
                f"{tile} B vs effective_budget "
                f"{model['effective_budget']} B (fits={model['fits']}) — "
                "bucket_ladder and the kernel tuner disagree about the "
                "weight reservation; a hand-pinned bucket is exceeding "
                "what fits_vmem accepts"))
    return findings, ladder


# ---------------------------------------------------------------------------
# Entry points.
# ---------------------------------------------------------------------------

def audit_path(spec, cfg, params, *, max_batch: int = 1024):
    """Full kernel-contract audit of one path.  ``params`` are raw;
    the path's own transform hook is applied first so the audit sees
    the serving-time pytree (quantized, split, ...)."""
    findings: list[Finding] = []
    tparams = spec.prepare_params(params)

    if not spec.pallas:
        # Containment: an XLA path must not smuggle a pallas_call.
        jaxpr = trace_forward(spec, cfg, tparams, 8)
        if find_pallas_calls(jaxpr.jaxpr):
            findings.append(Finding(
                "audit-containment", _loc(spec), 0,
                "path is registered pallas=False but its trace contains a "
                "pallas_call — fix the tag (serving's interpret-mode "
                "fallback keys on it) or move the kernel behind a "
                "pallas=True path"))
        return findings

    if spec.residency_model is None:
        findings.append(Finding(
            "audit-no-residency-model", _loc(spec), 0,
            "Pallas path declares no residency_model hook — the auditor "
            "cannot cross-check its BlockSpecs against a bytes model; "
            "expose modeled_residency() from the kernel's autotune module "
            "and wire it into the PathSpec"))
        return findings

    ladder_findings, ladder = _check_ladder(spec, cfg, tparams, max_batch)
    findings.extend(ladder_findings)

    for rung in ladder:
        model = spec.residency_model(cfg, tparams, rung)
        try:
            jaxpr = trace_forward(spec, cfg, tparams, rung)
        except Exception as exc:
            findings.append(Finding(
                "audit-trace-failure", _loc(spec, rung), 0,
                f"forward does not trace at bucket {rung}: "
                f"{type(exc).__name__}: {exc}"))
            continue
        kernels = [TracedKernel(e) for e in find_pallas_calls(jaxpr.jaxpr)]
        if not kernels:
            findings.append(Finding(
                "audit-containment", _loc(spec, rung), 0,
                "path is registered pallas=True but its trace contains no "
                "pallas_call — the tag is load-bearing for serving's "
                "interpret-mode fallback; fix it or restore the kernel"))
            continue
        findings.extend(_check_tiling(spec, rung, kernels, model))
        findings.extend(_check_weight_residency(spec, rung, kernels, model))
        findings.extend(_check_intermediates(spec, rung, kernels, model))
        findings.extend(_check_fp32_accumulation(spec, rung, kernels))
        if spec.quantized:
            findings.extend(_check_int8_discipline(spec, rung, kernels))
    return findings


def audit_registry(cfg, params, *, max_batch: int = 1024,
                   names=None):
    """Audit every registered path (or the named subset) plus the
    registry-level invariants: fallback chains resolve acyclically and
    every Pallas path carries a residency model."""
    from repro.core import paths as registry
    findings: list[Finding] = []
    try:
        registry.validate_fallbacks()
    except Exception as exc:
        findings.append(Finding(
            "audit-fallback-chain", "registry", 0,
            f"fallback-chain validation failed: {exc}"))
    for name in (names or registry.available()):
        spec = registry.get(name)
        findings.extend(audit_path(spec, cfg, params, max_batch=max_batch))
    return findings
