"""The one currency of the static-analysis subsystem: a Finding.

Both engines — the AST lint framework (:mod:`repro.analysis.lint`) and
the kernel-contract auditor (:mod:`repro.analysis.kernel_audit`) —
report through this type, so the CLI, CI job, and tier-1 test consume
one shape regardless of which engine spoke.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Finding:
    """One violated invariant.

    ``rule``     — stable rule/check identifier (kebab-case), the key the
                   ``analysis.toml`` allowlist and ``--rules`` filter use.
    ``location`` — repo-relative file path for lint findings; a
                   ``path=...` bucket=...`` coordinate for audit findings.
    ``line``     — 1-based source line when known, 0 otherwise.
    ``message``  — actionable: states the invariant, the observed value,
                   and what to change.
    """

    rule: str
    location: str
    line: int
    message: str

    def as_dict(self) -> dict:
        return {"rule": self.rule, "location": self.location,
                "line": self.line, "message": self.message}

    def render(self) -> str:
        where = f"{self.location}:{self.line}" if self.line else self.location
        return f"[{self.rule}] {where}: {self.message}"
