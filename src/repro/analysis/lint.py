"""AST lint engine: pluggable rules over the repo's tracked sources.

One ``LintContext`` walks the tree once (sources and parsed ASTs are
cached per run); each :class:`Rule` inspects what it cares about and
yields :class:`~repro.analysis.findings.Finding`s.  The per-rule
allowlist from ``analysis.toml`` is applied HERE, after the rule
speaks — rules stay exception-free, the config is the one audited
place where sanctioned violations live.

Adding a rule: write a module under ``analysis/rules/`` exposing a
class with ``name``, ``description`` and ``check(ctx, config)``, then
list it in ``rules/__init__.ALL_RULES``.  That's the whole protocol —
see any existing rule for the idiom.
"""

from __future__ import annotations

import ast
import os
import subprocess
from pathlib import Path
from typing import Iterable, Protocol

from repro.analysis.config import AnalysisConfig
from repro.analysis.findings import Finding

_SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", ".pytest_cache",
              "node_modules", ".venv", "venv"}


class Rule(Protocol):
    """The lint-rule protocol (structural — no base class to inherit)."""

    name: str
    description: str

    def check(self, ctx: "LintContext",
              config: AnalysisConfig) -> Iterable[Finding]: ...


class LintContext:
    """One repo snapshot shared by every rule in a run."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self._files: list[str] | None = None
        self._sources: dict[str, str] = {}
        self._trees: dict[str, ast.AST] = {}

    # -- file discovery ------------------------------------------------------

    def files(self) -> list[str]:
        """Repo-relative tracked files (git index; os.walk fallback so
        the engine still runs on an export without .git)."""
        if self._files is None:
            self._files = self._git_files() or self._walk_files()
        return self._files

    def _git_files(self) -> list[str]:
        try:
            out = subprocess.run(
                ["git", "ls-files"], cwd=self.root, capture_output=True,
                text=True, timeout=30)
        except (OSError, subprocess.TimeoutExpired):
            return []
        if out.returncode != 0:
            return []
        return [f for f in out.stdout.splitlines()
                if f and (self.root / f).is_file()]

    def _walk_files(self) -> list[str]:
        found = []
        for dirpath, dirnames, filenames in os.walk(self.root):
            dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
            for fn in filenames:
                rel = os.path.relpath(os.path.join(dirpath, fn), self.root)
                found.append(rel.replace(os.sep, "/"))
        return sorted(found)

    def python_files(self, prefix: str = "") -> list[str]:
        return [f for f in self.files()
                if f.endswith(".py") and f.startswith(prefix)]

    # -- cached content ------------------------------------------------------

    def source(self, rel: str) -> str:
        if rel not in self._sources:
            self._sources[rel] = (self.root / rel).read_text(
                encoding="utf-8", errors="replace")
        return self._sources[rel]

    def tree(self, rel: str) -> ast.AST:
        """Parsed AST, cached; syntax errors surface as a finding via
        :meth:`try_tree` rather than crashing the whole pass."""
        if rel not in self._trees:
            self._trees[rel] = ast.parse(self.source(rel), filename=rel)
        return self._trees[rel]

    def try_tree(self, rel: str):
        try:
            return self.tree(rel), None
        except SyntaxError as exc:
            return None, Finding(
                rule="syntax", location=rel, line=exc.lineno or 0,
                message=f"file does not parse: {exc.msg}")


def run_lint(root: Path, rules: Iterable[Rule],
             config: AnalysisConfig | None = None) -> list[Finding]:
    """Run ``rules`` over the repo at ``root``; allowlisted findings are
    dropped here so every rule reports unconditionally."""
    if config is None:
        config = AnalysisConfig.load(root)
    ctx = LintContext(root)
    findings: list[Finding] = []
    for rule in rules:
        for f in rule.check(ctx, config):
            if not config.allowed(f.rule, f.location):
                findings.append(f)
    findings.sort(key=lambda f: (f.location, f.line, f.rule))
    return findings
