"""Rule ``wall-clock``: serving code reads time only through clock seams.

The fault-injection harness and the deadline batcher tests depend on
every latency decision being driven through an injectable clock
(``clock=time.monotonic`` default arguments, ``self._clock`` fields).
A direct ``time.time()`` / ``time.perf_counter()`` CALL buried in a
serving module is untestable wall-clock coupling — the harness can't
freeze it, so deadline behavior silently drifts out of test coverage.

Bare ATTRIBUTE references (``clock=time.monotonic`` as a default, or
``getattr(engine, "_clock", time.monotonic)``) are exactly the seam
pattern and stay legal; only call sites are findings.  The modules
that OWN the seam (they must read the real clock somewhere) are
sanctioned in ``analysis.toml`` under ``[rules.wall-clock] allow``.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.config import AnalysisConfig
from repro.analysis.findings import Finding
from repro.analysis.lint import LintContext

SERVING_PREFIX = "src/repro/serving/"

CLOCK_NAMES = ("time", "monotonic", "perf_counter", "monotonic_ns",
               "perf_counter_ns", "process_time")


def _clock_calls(tree: ast.AST):
    """Yield (lineno, rendered) for direct wall-clock call sites: both
    ``time.X()`` attribute calls and bare ``X()`` calls on names
    imported via ``from time import X``."""
    from_time: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for a in node.names:
                if a.name in CLOCK_NAMES:
                    from_time.add(a.asname or a.name)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if (isinstance(fn, ast.Attribute) and fn.attr in CLOCK_NAMES
                and isinstance(fn.value, ast.Name) and fn.value.id == "time"):
            yield node.lineno, f"time.{fn.attr}()"
        elif isinstance(fn, ast.Name) and fn.id in from_time:
            yield node.lineno, f"{fn.id}()"


class WallClockRule:
    name = "wall-clock"
    description = ("no direct wall-clock calls in serving/ outside the "
                   "sanctioned clock-seam owners")

    def check(self, ctx: LintContext,
              config: AnalysisConfig) -> Iterable[Finding]:
        prefix = config.options.get(self.name, {}).get(
            "prefix", SERVING_PREFIX)
        for rel in ctx.python_files(prefix):
            tree, err = ctx.try_tree(rel)
            if err is not None:
                yield err
                continue
            for lineno, rendered in _clock_calls(tree):
                yield Finding(
                    self.name, rel, lineno,
                    f"direct {rendered} in serving code — read time through "
                    "the injectable clock seam (clock=time.monotonic "
                    "default / self._clock) so the fault harness can freeze "
                    "it, or sanction this module in analysis.toml")
