"""Rule ``pallas-containment``: ``pallas_call`` lives only in kernels/.

Every Pallas entry point must sit under ``src/repro/kernels/`` where
the autotuner models, the VMEM budget discipline, and the kernel-
contract auditor (:mod:`repro.analysis.kernel_audit`) can see it.  A
``pallas_call`` issued from core/, serving/ or a test dodges all
three — the registry wrapper + kernels-module split is the contract.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.config import AnalysisConfig
from repro.analysis.findings import Finding
from repro.analysis.lint import LintContext

KERNELS_PREFIX = "src/repro/kernels/"


def _is_pallas_call(node: ast.AST) -> bool:
    """Matches ``pl.pallas_call(...)`` / ``pallas_call(...)`` /
    ``jax.experimental.pallas.pallas_call(...)`` call sites."""
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id == "pallas_call"
    if isinstance(fn, ast.Attribute):
        return fn.attr == "pallas_call"
    return False


class PallasContainmentRule:
    name = "pallas-containment"
    description = "no pl.pallas_call call site outside src/repro/kernels/"

    def check(self, ctx: LintContext,
              config: AnalysisConfig) -> Iterable[Finding]:
        for rel in ctx.python_files():
            if rel.startswith(KERNELS_PREFIX):
                continue
            tree, err = ctx.try_tree(rel)
            if err is not None:
                yield err
                continue
            for node in ast.walk(tree):
                if _is_pallas_call(node):
                    yield Finding(
                        self.name, rel, node.lineno,
                        "pallas_call outside src/repro/kernels/ — kernels "
                        "live behind the kernels package so the autotuner "
                        "models and the kernel-contract auditor cover them")
