"""Built-in lint rules.

``ALL_RULES`` is the one registry the CLI and the tier-1 test resolve
rules through; add a new rule module here and it runs everywhere at
once.
"""

from repro.analysis.rules.pallas_containment import PallasContainmentRule
from repro.analysis.rules.register_path_decl import RegisterPathDeclRule
from repro.analysis.rules.retired_names import RetiredNamesRule
from repro.analysis.rules.thin_cli import ThinCliRule
from repro.analysis.rules.wall_clock import WallClockRule

ALL_RULES = (
    ThinCliRule(),
    RetiredNamesRule(),
    PallasContainmentRule(),
    WallClockRule(),
    RegisterPathDeclRule(),
)

__all__ = ["ALL_RULES", "ThinCliRule", "RetiredNamesRule",
           "PallasContainmentRule", "WallClockRule", "RegisterPathDeclRule"]
