"""Rule ``register-path-decl``: registration sites declare their ladder.

Every production path registration — a ``@register_path(...)``
decorator or a ``paths.register(paths.PathSpec(...))`` call under
``src/repro/`` — must state ``complexity`` (the aggregation class the
roofline and codesign reason about) and ``fallback`` (the degradation
rung, ``None`` explicitly for a terminal path) AT THE CALL SITE.  The
dataclass defaults would silently fill both in, which is exactly how a
new path ends up in the serving ladder with an unconsidered
degradation story; writing them out makes the reviewer see the
decision.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.config import AnalysisConfig
from repro.analysis.findings import Finding
from repro.analysis.lint import LintContext

SRC_PREFIX = "src/repro/"
REQUIRED_KEYWORDS = ("complexity", "fallback")


def _call_name(node: ast.Call) -> str:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return ""


def _registration_sites(tree: ast.AST):
    """Yield (kind, call) for every path-registration call site:
    ``register_path(...)`` and the ``PathSpec(...)`` argument of a
    ``register(...)`` call (bare PathSpec constructions elsewhere are
    not registrations and stay out of scope)."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name == "register_path":
            yield "@register_path", node
        elif name == "register":
            for arg in node.args:
                if isinstance(arg, ast.Call) and _call_name(arg) == "PathSpec":
                    yield "register(PathSpec)", arg


class RegisterPathDeclRule:
    name = "register-path-decl"
    description = ("every path registration site declares complexity and "
                   "fallback explicitly")

    def check(self, ctx: LintContext,
              config: AnalysisConfig) -> Iterable[Finding]:
        prefix = config.options.get(self.name, {}).get("prefix", SRC_PREFIX)
        for rel in ctx.python_files(prefix):
            tree, err = ctx.try_tree(rel)
            if err is not None:
                yield err
                continue
            for kind, call in _registration_sites(tree):
                if any(kw.arg is None for kw in call.keywords):
                    # **fields forwarding (the register_path decorator's
                    # own body) — the declaration is checked where the
                    # fields are actually written, i.e. the decorator
                    # call site.
                    continue
                given = {kw.arg for kw in call.keywords if kw.arg}
                missing = [k for k in REQUIRED_KEYWORDS if k not in given]
                if missing:
                    yield Finding(
                        self.name, rel, call.lineno,
                        f"{kind} site omits {', '.join(missing)} — declare "
                        "the aggregation class and the degradation rung "
                        "(fallback=None for a terminal path) at the call "
                        "site instead of inheriting dataclass defaults")
