"""Rule ``thin-cli``: launch CLIs stay thin shells over ``repro.serving``.

Ported from ``tests/test_thin_cli.py`` (the test is now a zero-findings
assertion over this rule).  A thin CLI module may contain ONLY: a
docstring, imports, simple constant assignments, a ``main`` function,
and the ``if __name__ == "__main__"`` block; ``main`` itself may only
build an argparse parser and delegate into ``repro.serving`` — no
loops, branches, nested defs, or numerics imports.  Logic that needs
any of those belongs behind the serving package where the event loop,
the benchmarks and the tests can reuse it.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.config import AnalysisConfig
from repro.analysis.findings import Finding
from repro.analysis.lint import LintContext

THIN_CLIS = ("src/repro/launch/trigger_serve.py", "src/repro/launch/serve.py")

# engine/batching logic needs numerics; a thin shell must not
FORBIDDEN_IMPORTS = ("jax", "numpy", "jax.numpy")
# the only repro package a thin CLI may reach into (stdlib is free)
ALLOWED_REPRO_PREFIX = "repro.serving"


def _imported_modules(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                yield a.name, node.lineno
        elif isinstance(node, ast.ImportFrom):
            yield node.module or "", node.lineno


class ThinCliRule:
    name = "thin-cli"
    description = ("launch CLIs hold only imports, constants, main() and "
                   "the __main__ guard, importing repro.serving alone")

    def check(self, ctx: LintContext,
              config: AnalysisConfig) -> Iterable[Finding]:
        clis = tuple(config.options.get(self.name, {}).get("paths", THIN_CLIS))
        for rel in clis:
            if not (ctx.root / rel).is_file():
                yield Finding(self.name, rel, 0,
                              "declared thin CLI module is missing")
                continue
            tree, err = ctx.try_tree(rel)
            if err is not None:
                yield err
                continue
            yield from self._check_top_level(rel, tree)
            yield from self._check_main(rel, tree)
            yield from self._check_imports(rel, tree)

    def _check_top_level(self, rel, tree):
        main_defs = 0
        has_guard = False
        for i, node in enumerate(tree.body):
            if i == 0 and isinstance(node, ast.Expr):
                continue                    # module docstring
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                continue
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue                    # simple module constants
            if isinstance(node, ast.FunctionDef):
                if node.name == "main":
                    main_defs += 1
                    continue
                yield Finding(
                    self.name, rel, node.lineno,
                    f"top-level def {node.name}() — thin CLIs define only "
                    "main(); move logic into repro.serving")
                continue
            if isinstance(node, ast.If):    # if __name__ == "__main__": main()
                cond = ast.unparse(node.test)
                if "__name__" in cond:
                    has_guard = True
                    continue
                yield Finding(
                    self.name, rel, node.lineno,
                    f"top-level `if {cond}` — only the __main__ guard is "
                    "allowed")
                continue
            yield Finding(
                self.name, rel, node.lineno,
                f"top-level {type(node).__name__} — thin CLI modules hold "
                "only imports, constants, main() and the __main__ guard; "
                "move logic into repro.serving")
        if main_defs != 1:
            yield Finding(
                self.name, rel, 0,
                f"expected exactly one main() definition, found {main_defs}")
        if not has_guard:
            yield Finding(
                self.name, rel, 0,
                'missing the `if __name__ == "__main__"` guard — the shell '
                "must stay runnable")

    def _check_main(self, rel, tree):
        main = next((n for n in tree.body
                     if isinstance(n, ast.FunctionDef) and n.name == "main"),
                    None)
        if main is None:
            return
        for node in ast.walk(main):
            if node is main:
                continue
            if isinstance(node, (ast.For, ast.While, ast.FunctionDef,
                                 ast.AsyncFunctionDef, ast.ClassDef,
                                 ast.Try, ast.With)):
                yield Finding(
                    self.name, rel, node.lineno,
                    f"{type(node).__name__} inside main() — batching/serving "
                    "logic belongs in repro.serving")
            elif isinstance(node, ast.If):
                yield Finding(
                    self.name, rel, node.lineno,
                    "branch inside main() — routing decisions belong in "
                    "repro.serving")

    def _check_imports(self, rel, tree):
        for mod, lineno in _imported_modules(tree):
            root = mod.split(".")[0]
            if root in FORBIDDEN_IMPORTS:
                yield Finding(
                    self.name, rel, lineno,
                    f"imports {mod!r} — a thin CLI has no numerics")
            elif root == "repro" and not (
                    mod == ALLOWED_REPRO_PREFIX
                    or mod.startswith(ALLOWED_REPRO_PREFIX + ".")):
                yield Finding(
                    self.name, rel, lineno,
                    f"imports {mod!r} — thin CLIs reach the framework only "
                    f"through {ALLOWED_REPRO_PREFIX}")
