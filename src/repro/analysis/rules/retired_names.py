"""Rule ``retired-names``: retired forward-path surfaces stay dead.

Ported from ``tests/test_repo_hygiene.py``'s grep guard.  The
pre-registry surfaces (the flat forward-fn mapping on
``interaction_net`` and the lazy path-name snapshots on the serving
package) must not creep back in via copy-paste from old branches: the
registry (``repro.core.paths``) is the one forward-path API.  The
sanctioned mentions (PR history, the issue text that ordered the
removal, the ruff ban list, this rule, and the legacy test shim) live
in ``analysis.toml`` under ``[rules.retired-names] allow`` — the ruff
TID251 bans stay as a second line of defense for imports.
"""

from __future__ import annotations

import re
from typing import Iterable

from repro.analysis.config import AnalysisConfig
from repro.analysis.findings import Finding
from repro.analysis.lint import LintContext

# Built by concatenation so this module does not match its own guard.
RETIRED_NAMES = ("FORWARD" + "_FNS", "PALLAS" + "_PATHS")


class RetiredNamesRule:
    name = "retired-names"
    description = ("no tracked text file mentions the retired pre-registry "
                   "forward-path surface names")

    def check(self, ctx: LintContext,
              config: AnalysisConfig) -> Iterable[Finding]:
        names = tuple(config.options.get(self.name, {}).get(
            "names", RETIRED_NAMES))
        pattern = re.compile("|".join(map(re.escape, names)))
        for rel in ctx.files():
            try:
                text = ctx.source(rel)
            except (OSError, UnicodeDecodeError):
                continue
            for i, line in enumerate(text.splitlines(), 1):
                if pattern.search(line):
                    yield Finding(
                        self.name, rel, i,
                        "retired forward-path surface name resurfaced "
                        "(use the repro.core.paths registry instead): "
                        f"{line.strip()!r}")
