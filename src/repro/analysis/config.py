"""``analysis.toml`` — per-rule allowlists and options.

The config file at the repo root scopes *sanctioned* violations (the
retired-name mentions in CHANGES.md, the direct wall-clock reads in the
serving modules that own the clock seam) so the engines themselves stay
allowlist-free: a rule reports everything it sees, and the config is
the single audited place where exceptions live.

Python 3.10 (the CI floor) has no ``tomllib``, and this repo adds no
dependencies, so a minimal TOML-subset parser backs it up.  The subset
is exactly what ``analysis.toml`` uses: ``[dotted.section]`` headers,
``key = "string"``, ``key = ["list", "of", "strings"]``, ``key = 123``,
``key = true/false``, and ``#`` comments.
"""

from __future__ import annotations

import fnmatch
from dataclasses import dataclass, field
from pathlib import Path

try:
    import tomllib  # Python >= 3.11
except ModuleNotFoundError:  # pragma: no cover - exercised on the 3.10 CI leg
    tomllib = None

CONFIG_NAME = "analysis.toml"


def _parse_toml_subset(text: str) -> dict:
    """Parse the TOML subset ``analysis.toml`` is written in.

    Fallback for Python 3.10 where ``tomllib`` is absent; intentionally
    strict — anything outside the subset raises so a config typo fails
    the analysis run instead of silently allowlisting nothing.
    """
    root: dict = {}
    table = root
    pending: tuple[str, int, list[str]] | None = None  # multi-line array
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.split("#", 1)[0].strip() if not raw.strip().startswith("#") \
            else ""
        if not line:
            continue
        if pending is not None:
            key, start, parts = pending
            parts.append(line)
            if line.endswith("]"):
                table[key] = _parse_value(" ".join(parts), start)
                pending = None
            continue
        if line.startswith("[") and line.endswith("]"):
            table = root
            for part in line[1:-1].strip().split("."):
                table = table.setdefault(part.strip(), {})
            continue
        if "=" not in line:
            raise ValueError(f"{CONFIG_NAME}:{lineno}: not key = value: {raw!r}")
        key, _, value = line.partition("=")
        key, value = key.strip(), value.strip()
        if value.startswith("[") and not value.endswith("]"):
            pending = (key, lineno, [value])
            continue
        table[key] = _parse_value(value, lineno)
    if pending is not None:
        raise ValueError(
            f"{CONFIG_NAME}:{pending[1]}: unterminated array for "
            f"{pending[0]!r}")
    return root


def _parse_value(value: str, lineno: int):
    if value.startswith("[") and value.endswith("]"):
        inner = value[1:-1].strip()
        if not inner:
            return []
        return [_parse_value(item.strip(), lineno)
                for item in inner.split(",") if item.strip()]
    if value.startswith('"') and value.endswith('"') and len(value) >= 2:
        return value[1:-1]
    if value in ("true", "false"):
        return value == "true"
    try:
        return int(value)
    except ValueError:
        raise ValueError(
            f"{CONFIG_NAME}:{lineno}: unsupported value {value!r} "
            "(subset: string, int, bool, list of those)") from None


@dataclass
class AnalysisConfig:
    """Loaded view of ``analysis.toml``.

    ``allow`` maps rule id -> list of repo-relative path patterns
    (``fnmatch`` syntax, so both exact files and ``src/**`` globs work);
    ``options`` maps rule id -> its ``[rules.<id>]`` table minus the
    ``allow`` key, for rules that take parameters.
    """

    allow: dict[str, list[str]] = field(default_factory=dict)
    options: dict[str, dict] = field(default_factory=dict)

    @classmethod
    def load(cls, root: Path) -> "AnalysisConfig":
        path = Path(root) / CONFIG_NAME
        if not path.is_file():
            return cls()
        text = path.read_text(encoding="utf-8")
        if tomllib is not None:
            data = tomllib.loads(text)
        else:
            data = _parse_toml_subset(text)
        allow: dict[str, list[str]] = {}
        options: dict[str, dict] = {}
        for rule, table in data.get("rules", {}).items():
            if not isinstance(table, dict):
                raise ValueError(
                    f"{CONFIG_NAME}: [rules.{rule}] must be a table")
            entries = table.get("allow", [])
            if not isinstance(entries, list):
                raise ValueError(
                    f"{CONFIG_NAME}: rules.{rule}.allow must be a list")
            allow[rule] = [str(e) for e in entries]
            opts = {k: v for k, v in table.items() if k != "allow"}
            if opts:
                options[rule] = opts
        return cls(allow=allow, options=options)

    def allowed(self, rule: str, location: str) -> bool:
        """True when ``location`` is sanctioned for ``rule``."""
        loc = location.replace("\\", "/")
        for pattern in self.allow.get(rule, ()):
            if loc == pattern or fnmatch.fnmatch(loc, pattern):
                return True
        return False
