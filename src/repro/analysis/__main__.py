"""``python -m repro.analysis`` — run the full static pass.

Both engines by default; ``--lint-only`` skips the (jax-importing)
kernel audit and ``--audit-only`` skips the AST rules.  Exit status 1
iff findings survive the ``analysis.toml`` allowlist — CI keys on
that, so does the tier-1 test.
"""

from __future__ import annotations

import argparse
import importlib
import json
import pathlib
import sys
import time


def _find_root(start: pathlib.Path) -> pathlib.Path:
    for cand in (start, *start.parents):
        if (cand / ".git").exists() or (cand / "analysis.toml").is_file():
            return cand
    return start


def _lint(root, rule_names):
    from repro.analysis.config import AnalysisConfig
    from repro.analysis.lint import run_lint
    from repro.analysis.rules import ALL_RULES
    rules = list(ALL_RULES)
    if rule_names:
        known = {r.name for r in rules}
        unknown = set(rule_names) - known
        if unknown:
            raise SystemExit(
                f"unknown rule(s) {sorted(unknown)}; available: {sorted(known)}")
        rules = [r for r in rules if r.name in rule_names]
    return run_lint(root, rules, AnalysisConfig.load(root))


def _audit(arch: str, max_batch: int, path_names):
    import jax

    from repro.analysis.kernel_audit import audit_registry
    from repro.core import interaction_net
    cfg = importlib.import_module(f"repro.configs.{arch}").MODEL
    params = interaction_net.init(jax.random.PRNGKey(0), cfg)
    return audit_registry(cfg, params, max_batch=max_batch,
                          names=path_names or None)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis: AST lint rules + kernel-contract audit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as a JSON document")
    ap.add_argument("--rules", default="",
                    help="comma-separated lint rule subset (default: all)")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--lint-only", action="store_true",
                      help="AST rules only (no jax import)")
    mode.add_argument("--audit-only", action="store_true",
                      help="kernel-contract audit only")
    ap.add_argument("--paths", default="",
                    help="comma-separated registered path subset to audit")
    ap.add_argument("--arch", default="jedi_30p",
                    help="config module under repro.configs (default: "
                         "jedi_30p)")
    ap.add_argument("--max-batch", type=int, default=1024,
                    help="bucket-ladder ceiling for the audit")
    ap.add_argument("--root", default=None,
                    help="repo root (default: discovered from cwd)")
    args = ap.parse_args(argv)

    root = (pathlib.Path(args.root).resolve() if args.root
            else _find_root(pathlib.Path.cwd().resolve()))
    rule_names = [r.strip() for r in args.rules.split(",") if r.strip()]
    path_names = [p.strip() for p in args.paths.split(",") if p.strip()]

    findings = []
    timings = {}
    if not args.audit_only:
        t0 = time.perf_counter()
        findings += _lint(root, rule_names)
        timings["lint_s"] = round(time.perf_counter() - t0, 3)
    if not args.lint_only:
        t0 = time.perf_counter()
        findings += _audit(args.arch, args.max_batch, path_names)
        timings["audit_s"] = round(time.perf_counter() - t0, 3)

    if args.as_json:
        print(json.dumps({"findings": [f.as_dict() for f in findings],
                          "count": len(findings), "timings": timings},
                         indent=2))
    else:
        for f in findings:
            print(f.render())
        engines = " + ".join(f"{k[:-2]} {v:.2f}s" for k, v in timings.items())
        status = "clean" if not findings else f"{len(findings)} finding(s)"
        print(f"repro.analysis: {status} ({engines})", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
