"""Data pipeline + training substrate tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import graphs, jets, lm_data, recsys_data
from repro.data.neighbor_sampler import (
    CSRGraph, minibatch_stream, sample_subgraph, static_budget)
from repro.training import make_optimizer, make_train_step
from repro.training.schedule import warmup_cosine, wsd


# --- neighbor sampler --------------------------------------------------------

def test_csr_neighbors_are_real_neighbors(rng):
    n, e = 50, 300
    s = rng.randint(0, n, e).astype(np.int32)
    r = rng.randint(0, n, e).astype(np.int32)
    csr = CSRGraph(n, s, r)
    adj = {i: set() for i in range(n)}
    for a, b in zip(s, r):
        adj[int(a)].add(int(b))
    nodes = np.arange(n, dtype=np.int32)
    nb = csr.sample_neighbors(rng, nodes, 7)
    for i in range(n):
        for x in nb[i]:
            if adj[i]:
                assert int(x) in adj[i], (i, x)
            else:
                assert int(x) == i          # isolated -> self


def test_subgraph_edges_are_valid(rng):
    n, e = 200, 2000
    g = graphs.community_graph(0, n, e, 16, n_classes=4)
    csr = CSRGraph(n, g["senders"], g["receivers"])
    seeds = rng.choice(n, 16, replace=False).astype(np.int32)
    mn, me = static_budget(16, (5, 3))
    sub = sample_subgraph(csr, rng, seeds, (5, 3), g["x"], g["y"], mn, me)
    assert sub["x"].shape == (mn, 16)
    em = sub["edge_mask"]
    # valid edges index real (non-pad) nodes
    n_sub = int(sub["n_nodes"])
    assert np.all(sub["senders"][em] < n_sub)
    assert np.all(sub["receivers"][em] < n_sub)
    # all seeds present with labels
    assert sub["seed_mask"].sum() == 16
    assert np.all(sub["y"][sub["seed_mask"]] >= 0)


def test_minibatch_stream_fixed_shapes():
    g = graphs.community_graph(1, 500, 5000, 8, n_classes=3)
    it = minibatch_stream(0, g, batch_nodes=32, fanout=(4, 3))
    a = next(it)
    b = next(it)
    assert a["x"].shape == b["x"].shape
    assert a["senders"].shape == b["senders"].shape
    assert not np.array_equal(a["y"], b["y"])     # different batches


# --- generators --------------------------------------------------------------

def test_jets_shapes_and_classes(rng):
    x, y = jets.make_jets(rng, 64, 30)
    assert x.shape == (64, 30, 16) and y.shape == (64,)
    assert set(np.unique(y)) <= set(range(5))
    assert np.all(np.isfinite(x))


def test_lm_bigram_is_learnable_structure(rng):
    t = lm_data.make_tokens(rng, 8, 64, vocab=100, branching=4)
    # each (prev, next) pair must come from the fixed bigram table
    nexts = lm_data._bigram_table(100, 4)
    for b in range(8):
        for i in range(1, 64):
            assert t[b, i] in nexts[t[b, i - 1]]


def test_ctr_labels_correlate_with_planted_rule():
    it = recsys_data.ctr_batches(0, 4096, (50, 40, 30))
    b = next(it)
    assert b["ids"].shape == (4096, 3)
    assert 0.1 < b["y"].mean() < 0.9      # non-degenerate


# --- schedules ---------------------------------------------------------------

def test_warmup_cosine_shape():
    f = warmup_cosine(1.0, 10, 100)
    assert float(f(0)) == 0.0
    assert float(f(10)) == pytest.approx(1.0, rel=1e-5)
    assert float(f(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(f(55)) < 1.0


def test_wsd_three_phases():
    f = wsd(1.0, 10, 100, decay_frac=0.2)
    assert float(f(5)) == pytest.approx(0.5, rel=1e-5)     # warmup
    assert float(f(50)) == pytest.approx(1.0, rel=1e-6)    # stable
    assert float(f(79)) == pytest.approx(1.0, rel=1e-6)    # still stable
    assert float(f(100)) == pytest.approx(0.01, rel=1e-2)  # decayed
    # decay is monotone
    vals = [float(f(s)) for s in range(80, 101)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


# --- optimizers --------------------------------------------------------------

@pytest.mark.parametrize("name,lr", [("sgd", 0.02), ("adamw", 0.1),
                                     ("adafactor", 0.1)])
def test_optimizer_reduces_quadratic(name, lr):
    from repro.training.schedule import constant
    opt = make_optimizer(name, constant(lr))
    target = jnp.asarray(np.random.RandomState(0).normal(0, 1, (16, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((16, 16))}
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}

    def loss(p, _):
        # sum (not mean) keeps gradient scale O(1) for momentum SGD
        return jnp.sum(jnp.square(p["w"] - target)), {}

    step = jax.jit(make_train_step(loss, opt))
    l0 = None
    for i in range(150):
        state, m = step(state, {})
        l0 = l0 if l0 is not None else float(m["loss"])
    assert float(m["loss"]) < 0.1 * l0


def test_adafactor_memory_is_sublinear():
    """Factored accumulators: state for a (512, 512) matrix is O(n) not
    O(n^2)."""
    from repro.training.schedule import constant
    opt = make_optimizer("adafactor", constant(1e-3))
    params = {"w": jnp.zeros((512, 512))}
    st = opt.init(params)
    n_state = sum(np.prod(l.shape) for l in
                  jax.tree_util.tree_leaves(st))
    assert n_state == 1024              # r (512) + c (512)


def test_grad_accum_equivalence():
    from repro.training.schedule import constant
    opt = make_optimizer("adamw", constant(1e-2))
    target = jnp.asarray(np.random.RandomState(0).normal(0, 1, (8,)),
                         jnp.float32)

    def loss(p, b):
        pred = b["x"] @ p["w"]
        return jnp.mean(jnp.square(pred - b["x"] @ target)), {}

    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    params = {"w": jnp.zeros((8,))}
    s1 = {"params": params, "opt": opt.init(params),
          "step": jnp.zeros((), jnp.int32)}
    s2 = jax.tree_util.tree_map(lambda a: a, s1)
    step1 = jax.jit(make_train_step(loss, opt))
    step4 = jax.jit(make_train_step(loss, opt, grad_accum=4))
    s1, m1 = step1(s1, {"x": x})
    s2, m2 = step4(s2, {"x": x})
    np.testing.assert_allclose(np.asarray(s1["params"]["w"]),
                               np.asarray(s2["params"]["w"]),
                               rtol=1e-5, atol=1e-6)


# --- checkpoint --------------------------------------------------------------

def test_checkpoint_atomicity_and_retention(tmp_path):
    from repro.checkpoint import CheckpointManager
    cm = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(10), "b": [jnp.ones((2, 2)),
                                       {"c": jnp.zeros(3)}]}
    for s in (1, 2, 3, 4):
        cm.save(s, tree)
    assert cm.all_steps() == [3, 4]
    restored, step = cm.restore()
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(10))
    np.testing.assert_array_equal(np.asarray(restored["b"][1]["c"]),
                                  np.zeros(3))


def test_checkpoint_async_then_sync(tmp_path):
    from repro.checkpoint import CheckpointManager
    cm = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((128, 128))}
    cm.save_async(10, tree)
    cm.wait()
    r, s = cm.restore()
    assert s == 10
    assert float(r["w"].sum()) == 128 * 128
