"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adjacency
from repro.models.gnn import segment_ops as seg
from repro.models.gnn import so3

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# --- adjacency strength reduction -------------------------------------------

@given(st.integers(min_value=2, max_value=40))
def test_edge_maps_cover_all_offdiagonal_pairs(n):
    recv, send = adjacency.edge_index_maps(n)
    pairs = set(zip(recv.tolist(), send.tolist()))
    assert len(pairs) == n * (n - 1)
    assert all(r != s for r, s in pairs)
    # receiver-major: edges of receiver i are contiguous
    assert np.all(np.diff(recv) >= 0)


@given(st.integers(min_value=2, max_value=12),
       st.integers(min_value=1, max_value=6))
def test_sr_b_matrix_equals_dense_product(n, p):
    """B1/B2 via strength reduction == I @ Rr / I @ Rs for random I."""
    from repro.core.interaction_net import JediNetConfig, build_b_matrix
    rng = np.random.RandomState(n * 7 + p)
    x = jnp.asarray(rng.normal(0, 1, (1, n, p)).astype(np.float32))
    cfg = JediNetConfig(n_objects=n, n_features=p)
    b = np.asarray(build_b_matrix(cfg, x)[0])          # (N_E, 2P)
    rr, rs = adjacency.dense_relation_matrices(n)
    i_mat = np.asarray(x[0]).T                         # (P, N_o)
    b1 = (i_mat @ rr).T
    b2 = (i_mat @ rs).T
    np.testing.assert_allclose(b[:, :p], b1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b[:, p:], b2, rtol=1e-5, atol=1e-6)


# --- segment ops -------------------------------------------------------------

@st.composite
def _segments(draw):
    n = draw(st.integers(min_value=1, max_value=10))
    e = draw(st.integers(min_value=0, max_value=40))
    ids = draw(st.lists(st.integers(min_value=0, max_value=n - 1),
                        min_size=e, max_size=e))
    return n, np.asarray(ids, np.int32)


@given(_segments())
def test_segment_sum_is_linear_and_complete(args):
    n, ids = args
    rng = np.random.RandomState(len(ids))
    m = jnp.asarray(rng.normal(0, 1, (len(ids), 3)).astype(np.float32))
    s = seg.scatter_sum(m, jnp.asarray(ids), n)
    # total mass conservation
    np.testing.assert_allclose(np.asarray(s).sum(0), np.asarray(m).sum(0),
                               rtol=1e-4, atol=1e-4)
    # linearity
    s2 = seg.scatter_sum(2.0 * m, jnp.asarray(ids), n)
    np.testing.assert_allclose(np.asarray(s2), 2 * np.asarray(s),
                               rtol=1e-5, atol=1e-5)


@given(_segments())
def test_segment_mean_max_min_bounds(args):
    n, ids = args
    if len(ids) == 0:
        return
    rng = np.random.RandomState(len(ids) + 1)
    m = jnp.asarray(rng.normal(0, 1, (len(ids),)).astype(np.float32))
    mean = np.asarray(seg.scatter_mean(m, jnp.asarray(ids), n))
    mx = np.asarray(seg.scatter_max(m, jnp.asarray(ids), n))
    mn = np.asarray(seg.scatter_min(m, jnp.asarray(ids), n))
    present = np.bincount(ids, minlength=n) > 0
    assert np.all(mn[present] <= mean[present] + 1e-5)
    assert np.all(mean[present] <= mx[present] + 1e-5)
    # empty segments are exactly 0, never +-inf
    assert np.all(np.isfinite(mx)) and np.all(np.isfinite(mn))
    assert np.all(mx[~present] == 0) and np.all(mn[~present] == 0)


@given(_segments())
def test_segment_softmax_normalizes(args):
    n, ids = args
    if len(ids) == 0:
        return
    rng = np.random.RandomState(len(ids) + 2)
    scores = jnp.asarray(rng.normal(0, 3, (len(ids),)).astype(np.float32))
    p = seg.segment_softmax(scores, jnp.asarray(ids), n)
    sums = np.asarray(seg.scatter_sum(p, jnp.asarray(ids), n))
    present = np.bincount(ids, minlength=n) > 0
    np.testing.assert_allclose(sums[present], 1.0, rtol=1e-4, atol=1e-4)
    assert np.all(np.asarray(p) >= 0)


# --- FM strength reduction ---------------------------------------------------

@given(st.integers(min_value=2, max_value=10),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=6))
def test_fm_sum_square_identity(f, k, b):
    from repro.models.recsys import fm_interaction
    rng = np.random.RandomState(f * 31 + k)
    v = jnp.asarray(rng.normal(0, 1, (b, f, k)).astype(np.float32))
    naive = sum(jnp.sum(v[:, i] * v[:, j], -1)
                for i in range(f) for j in range(i + 1, f))
    fast = fm_interaction(v)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(fast),
                               rtol=2e-4, atol=2e-4)


# --- SO(3) equivariance ------------------------------------------------------

@given(st.integers(min_value=0, max_value=3),
       st.integers(min_value=0, max_value=100))
def test_wigner_rotation_consistency(l_max, seed):
    """Y(R r) == D(R) Y(r) for the J-matrix fast path (align-to-z)."""
    rng = np.random.RandomState(seed)
    d = rng.normal(0, 1, 3)
    d = d / np.linalg.norm(d)
    dirs = jnp.asarray(d[None, :].astype(np.float32))
    blocks = so3.wigner_align_z(l_max, dirs)
    y = so3.real_sph_harm(l_max, dirs)                  # (1, K)
    # rotated SH: direction becomes +z
    z = jnp.asarray(np.array([[0.0, 0.0, 1.0]], np.float32))
    y_z = so3.real_sph_harm(l_max, z)
    got = so3.apply_wigner(blocks, y[..., None])[..., 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_z),
                               rtol=2e-3, atol=2e-3)


@given(st.integers(min_value=0, max_value=60))
def test_wigner_blocks_orthogonal(seed):
    rng = np.random.RandomState(seed)
    d = rng.normal(0, 1, 3)
    d = d / np.linalg.norm(d)
    blocks = so3.wigner_align_z(3, jnp.asarray(d[None, :].astype(np.float32)))
    for l, blk in enumerate(blocks):
        m = np.asarray(blk[0])
        np.testing.assert_allclose(m @ m.T, np.eye(2 * l + 1),
                                   rtol=1e-3, atol=1e-3)


# --- quantization round trip --------------------------------------------------

@given(st.integers(min_value=0, max_value=50))
def test_quantize_error_bound(seed):
    from repro.training.grad_compression import quantize, dequantize
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.normal(0, rng.uniform(0.01, 10),
                               (64,)).astype(np.float32))
    q, scale = quantize(x, bits=8)
    err = np.abs(np.asarray(dequantize(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-7     # half-ulp bound
