"""Fault-injection (chaos) suite for the resilient serving layer.

Every degraded-mode transition the ISSUE's acceptance demands, driven
deterministically on CPU through :mod:`repro.serving.faults`:
demote-on-compile-failure, demote-on-NaN, watchdog on stuck dispatches,
exponential-backoff re-promotion probes, deadline shedding, bounded
in-flight backpressure, the health state machine, and the headline
guarantee — with faults firing, every non-shed request is served via a
fallback path with ZERO exceptions escaping the serve loop.

All tests carry the ``chaos`` marker: they run in tier-1 and standalone
in CI's dedicated chaos job (``pytest -m chaos``), which is kept out of
the serialized perf-gate job so injected sleeps never pollute the
benchmark calibration window.
"""

import math

import jax
import numpy as np
import pytest

from repro.core import paths
from repro.core.interaction_net import JediNetConfig, forward_sr, init
from repro.serving import (
    DeadlineBatcher,
    FaultInjector,
    InjectedFault,
    ResilientEngine,
    ServingEngine,
    WatchdogTimeout,
)
from repro.serving.faults import StuckBuffer

pytestmark = pytest.mark.chaos


@pytest.fixture(scope="module")
def jedi8():
    cfg = JediNetConfig(n_objects=8, n_features=16)
    params = init(jax.random.PRNGKey(0), cfg, scale="lecun")
    rng = np.random.RandomState(0)
    x = rng.normal(0, 1, (5, 8, 16)).astype(np.float32)
    ref = np.asarray(forward_sr(params, cfg, x))
    return cfg, params, x, ref


def _engine(jedi, injector=None, **kw):
    cfg, params, _, _ = jedi
    kw.setdefault("forward", "fused_full")
    kw.setdefault("interpret", True)
    kw.setdefault("max_batch", 16)
    return ResilientEngine(params, cfg, injector=injector, **kw)


# -- injector unit behavior ----------------------------------------------


def test_injector_times_budget_and_log():
    inj = FaultInjector()
    f = inj.arm("compile", path="p", bucket=8, times=2)
    for _ in range(2):
        with pytest.raises(InjectedFault):
            inj.check("compile", path="p", bucket=8)
    inj.check("compile", path="p", bucket=8)        # budget spent: no raise
    assert not f.armed and f.fired == 2
    assert inj.log == [("compile", "p", 8)] * 2
    assert inj.fired("compile") == 2 and inj.fired("dispatch") == 0


def test_injector_scoping_by_path_and_bucket():
    inj = FaultInjector()
    inj.arm("dispatch", path="a", bucket=16)
    inj.check("dispatch", path="b", bucket=16)      # other path: no fire
    inj.check("dispatch", path="a", bucket=8)       # other bucket: no fire
    with pytest.raises(InjectedFault):
        inj.check("dispatch", path="a", bucket=16)


def test_injector_rejects_unknown_seam():
    with pytest.raises(ValueError):
        FaultInjector().arm("segfault")


def test_injector_input_nan_and_output_nan():
    inj = FaultInjector()
    inj.arm("input_nan", times=1)
    x = np.ones((3, 2), np.float32)
    bad = inj.corrupt_input(x)
    assert np.isnan(bad[0]).all() and np.isfinite(bad[1:]).all()
    assert np.isfinite(x).all()                     # original untouched
    assert inj.corrupt_input(x) is x                # budget spent

    inj.arm("output_nan", times=1)
    out = inj.wrap_output(np.zeros((4, 2), np.float32))
    assert out.shape == (4, 2) and np.isnan(out).all()


def test_stuck_buffer_ready_transition():
    t = [0.0]
    buf = StuckBuffer(np.arange(6.0).reshape(2, 3), ready_at=5.0,
                      clock=lambda: t[0])
    assert not buf.is_ready()
    t[0] = 5.0
    assert buf.is_ready()
    assert np.asarray(buf).shape == (2, 3)
    assert buf.shape == (2, 3)


# -- ServingEngine seams + watchdog --------------------------------------


def test_engine_compile_seam_fires_on_cache_miss_only(jedi8):
    cfg, params, x, ref = jedi8
    inj = FaultInjector()
    inj.arm("compile", path="sr", times=1)
    eng = ServingEngine(params, cfg, forward="sr", max_batch=16,
                        injector=inj)
    with pytest.raises(InjectedFault):
        eng.infer(x)                                 # cold cache: seam fires
    out = eng.infer(x)                               # budget spent: compiles
    assert np.abs(out - ref).max() < 1e-4
    inj.arm("compile", path="sr", times=math.inf)
    out = eng.infer(x)                               # warm cache: cannot fire
    assert np.abs(out - ref).max() < 1e-4
    assert inj.fired("compile") == 1


def test_engine_watchdog_times_out_stuck_dispatch(jedi8):
    cfg, params, x, _ = jedi8
    inj = FaultInjector()
    inj.arm("stuck", times=1, delay_s=60.0)
    eng = ServingEngine(params, cfg, forward="sr", max_batch=16,
                        injector=inj)
    with pytest.raises(WatchdogTimeout):
        eng.infer(x, timeout_s=0.05)
    # next dispatch is clean and still serves
    assert eng.infer(x, timeout_s=5.0).shape == (5, cfg.n_targets)


# -- degradation ladder ---------------------------------------------------


def test_compile_failure_demotes_and_fallback_serves(jedi8):
    cfg, params, x, ref = jedi8
    inj = FaultInjector()
    inj.arm("compile", path="fused_full", times=math.inf)
    eng = _engine(jedi8, inj)
    out = eng.infer(x)
    assert np.abs(out - ref).max() < 1e-4
    h = eng.health()
    assert h["state"] == "degraded"
    (detail,) = h["buckets"].values()
    assert detail["path"] == "sr_split" and detail["demotions"] == 1
    assert eng.metrics.counter("compile_failures") == 1
    assert eng.metrics.counter("demotions") == 1
    assert eng.metrics.counter("fallback_batches") == 1


def test_jedi_linear_full_demotes_to_xla_same_model(jedi8):
    """The jedi-linear ladder's first rung down is the SAME model in
    XLA: a kernel compile failure degrades latency, not predictions."""
    from repro.kernels.jedi_linear import ref as jl_ref

    cfg, params, x, _ = jedi8
    inj = FaultInjector()
    inj.arm("compile", path="jedi_linear_full", times=math.inf)
    eng = _engine(jedi8, inj, forward="jedi_linear_full")
    out = eng.infer(x)
    ref = np.asarray(jl_ref.forward_jedi_linear(params, cfg, x))
    assert np.abs(out - ref).max() < paths.get("jedi_linear").tolerance
    (detail,) = eng.health()["buckets"].values()
    assert detail["path"] == "jedi_linear" and detail["demotions"] == 1


def test_int8_jedi_ladder_walks_two_rungs(jedi8):
    """Both Pallas rungs of the int8 jedi chain failing to compile
    walks the ladder to the XLA rung in a single serve."""
    cfg, params, x, _ = jedi8
    inj = FaultInjector()
    inj.arm("compile", path="int8_jedi_linear_full", times=math.inf)
    inj.arm("compile", path="jedi_linear_full", times=math.inf)
    eng = _engine(jedi8, inj, forward="int8_jedi_linear_full")
    out = eng.infer(x)
    assert np.isfinite(out).all() and out.shape == (5, cfg.n_targets)
    (detail,) = eng.health()["buckets"].values()
    assert detail["path"] == "jedi_linear" and detail["demotions"] == 2
    assert eng.health()["state"] == "degraded"


def test_resilient_chains_match_registry_for_jedi_paths(jedi8):
    """ResilientEngine's ladder is exactly the registry chain, and every
    jedi chain terminates on a non-Pallas rung it can always serve."""
    for name in ("jedi_linear", "jedi_linear_full", "int8_jedi_linear_full"):
        eng = _engine(jedi8, forward=name)
        assert eng.chain == paths.fallback_chain(name)
        assert not paths.get(eng.chain[-1]).pallas


def test_nonfinite_output_demotes_and_reserves(jedi8):
    cfg, params, x, ref = jedi8
    inj = FaultInjector()
    inj.arm("output_nan", path="fused_full", times=1)
    eng = _engine(jedi8, inj)
    out = eng.infer(x)
    assert np.isfinite(out).all()
    assert np.abs(out - ref).max() < 1e-4
    assert eng.metrics.counter("nonfinite_batches") == 1
    assert eng.active_path(eng.bucket_for(5)) == "sr_split"


def test_path_scoped_input_nan_recovers_on_fallback(jedi8):
    """A NaN batch poisoning ONE path (bad scale, DMA flip) must not
    poison the fallback: outputs match the reference after demotion."""
    cfg, params, x, ref = jedi8
    inj = FaultInjector()
    inj.arm("input_nan", path="fused_full", times=math.inf)
    eng = _engine(jedi8, inj)
    out = eng.infer(x)
    assert np.abs(out - ref).max() < 1e-4
    assert eng.metrics.counter("nonfinite_batches") >= 1


def test_stuck_dispatch_watchdog_demotes(jedi8):
    cfg, params, x, ref = jedi8
    inj = FaultInjector()
    inj.arm("stuck", path="fused_full", times=1, delay_s=60.0)
    eng = _engine(jedi8, inj, watchdog_s=0.05)
    out = eng.infer(x)
    assert np.abs(out - ref).max() < 1e-4
    assert eng.metrics.counter("watchdog_timeouts") == 1
    assert eng.health()["state"] == "degraded"


def test_whole_ladder_failure_is_down_not_raise(jedi8):
    cfg, params, x, _ = jedi8
    t = [0.0]
    inj = FaultInjector()
    inj.arm("dispatch", times=math.inf)             # every path, every bucket
    eng = _engine(jedi8, inj, clock=lambda: t[0])
    out = eng.infer(x)                              # must NOT raise
    assert out.shape == (5, cfg.n_targets) and np.isnan(out).all()
    assert eng.health()["state"] == "down"
    assert eng.metrics.counter("failed_requests") == 1
    # faults cleared + probe due: the next serve recovers and clears down
    inj.disarm()
    t[0] = 100.0
    assert np.isfinite(eng.infer(x)).all()
    assert eng.health()["state"] != "down"


# -- re-promotion probes --------------------------------------------------


def test_exponential_backoff_repromotion(jedi8):
    cfg, params, x, ref = jedi8
    t = [0.0]
    inj = FaultInjector(clock=lambda: t[0])
    inj.arm("output_nan", path="fused_full", times=2)
    eng = _engine(jedi8, inj, probe_initial_s=1.0, probe_max_s=8.0,
                  clock=lambda: t[0])
    bucket = eng.bucket_for(5)

    eng.infer(x)                                     # fault 1: demote
    st = eng._bucket_state(bucket)
    assert eng.active_path(bucket) == "sr_split"
    assert st.next_probe == pytest.approx(1.0) and st.backoff_s == 2.0

    t[0] = 0.5
    eng.infer(x)                                     # probe not due yet
    assert eng.metrics.counter("probes") == 0

    t[0] = 1.5
    eng.infer(x)                                     # probe: fault 2 burns it
    assert eng.metrics.counter("probes") == 1
    assert eng.active_path(bucket) == "sr_split"     # still demoted
    assert st.next_probe == pytest.approx(1.5 + 2.0) # backoff doubled
    assert st.backoff_s == 4.0

    t[0] = 4.0
    out = eng.infer(x)                               # probe: budget spent -> ok
    assert np.abs(out - ref).max() < 1e-4
    assert eng.active_path(bucket) == "fused_full"   # re-promoted
    assert eng.metrics.counter("promotions") == 1
    assert st.backoff_s == 1.0                       # backoff reset
    assert eng.health()["state"] == "healthy"


# -- deadline enforcement + shedding -------------------------------------


def test_expired_request_is_shed_never_dispatched(jedi8):
    cfg, params, x, _ = jedi8
    t = [10.0]
    eng = _engine(jedi8, clock=lambda: t[0])
    out = eng.infer(x, deadline=9.0)
    assert out is None
    assert eng.metrics.counter("shed_requests") == 1
    assert eng.metrics.counter("shed_events") == 5
    assert eng.metrics.batches == 0                  # nothing dispatched
    assert eng.health()["state"] == "shedding"
    # shedding decays back to healthy outside the window
    t[0] += eng.shed_window_s + 1
    assert eng.health()["state"] == "healthy"


def test_run_plan_sheds_expired_segments_serves_rest(jedi8):
    cfg, params, _, _ = jedi8
    t = [0.0]
    eng = _engine(jedi8, clock=lambda: t[0])
    bat = DeadlineBatcher(eng.bucket_sizes, deadline_s=1.0,
                          clock=lambda: t[0])
    rng = np.random.RandomState(1)
    xs = {1: rng.normal(0, 1, (2, 8, 16)).astype(np.float32),
          2: rng.normal(0, 1, (3, 8, 16)).astype(np.float32)}
    bat.submit(1, xs[1], deadline_s=0.5)             # will expire
    bat.submit(2, xs[2], deadline_s=60.0)            # plenty of budget
    t[0] = 2.0                                       # rid 1 now expired
    (plan,) = bat.flush()
    res = eng.run_plan(plan)
    assert res[1] is None                            # shed
    ref2 = np.asarray(forward_sr(params, cfg, xs[2]))
    assert np.abs(res[2] - ref2).max() < 1e-4        # served
    assert eng.metrics.counter("shed_events") == 2


def test_run_plan_without_deadlines_serves_everything(jedi8):
    cfg, params, _, _ = jedi8
    eng = _engine(jedi8)
    bat = DeadlineBatcher(eng.bucket_sizes, clock=lambda: 0.0)
    x = np.random.RandomState(2).normal(0, 1, (4, 8, 16)).astype(np.float32)
    bat.submit(7, x)
    (plan,) = bat.flush()
    res = eng.run_plan(plan)
    assert res[7].shape == (4, cfg.n_targets)
    assert eng.metrics.counter("shed_requests") == 0


# -- async path: bounded inflight + realization-time recovery ------------


def test_async_inflight_is_bounded_backpressure(jedi8):
    cfg, params, x, _ = jedi8
    eng = _engine(jedi8, max_inflight=2)
    handles = [eng.infer(x, sync=False) for _ in range(5)]
    assert len(eng._inflight) <= 2                   # queue stayed bounded
    outs = [h.result() for h in handles]
    assert all(o.shape == (5, cfg.n_targets) for o in outs)
    assert len(eng._inflight) == 0


def test_async_realization_recovers_from_stuck(jedi8):
    cfg, params, x, ref = jedi8
    inj = FaultInjector()
    inj.arm("stuck", path="fused_full", times=1, delay_s=60.0)
    eng = _engine(jedi8, inj, watchdog_s=0.05)
    h = eng.infer(x, sync=False)
    out = h.result()                                 # watchdog + fallback
    assert np.abs(out - ref).max() < 1e-4
    assert eng.metrics.counter("watchdog_timeouts") == 1
    assert h.result() is out                         # idempotent


def test_async_dispatch_failure_falls_back_at_dispatch(jedi8):
    cfg, params, x, ref = jedi8
    inj = FaultInjector()
    inj.arm("compile", path="fused_full", times=math.inf)
    eng = _engine(jedi8, inj)
    out = eng.infer(x, sync=False).result()
    assert np.abs(out - ref).max() < 1e-4
    assert eng.metrics.counter("compile_failures") >= 1


# -- the headline guarantee ----------------------------------------------


def test_zero_exceptions_under_rotating_faults(jedi8):
    """ISSUE acceptance: with NaN batches, forced compile failures and
    stuck dispatches injected, every non-shed request is served via a
    fallback with zero raised exceptions, and the shed/demotion/
    re-promotion counts land in metrics."""
    cfg, params, _, _ = jedi8
    rng = np.random.RandomState(3)
    inj = FaultInjector()
    inj.arm("output_nan", path="fused_full", times=2)
    inj.arm("compile", path="fused_full", bucket=16, times=1)
    inj.arm("stuck", path="fused_full", times=1, delay_s=60.0)
    inj.arm("dispatch", path="fused_full", times=1)
    eng = _engine(jedi8, inj, watchdog_s=0.05, probe_initial_s=0.0)

    served = shed = 0
    for i in range(30):
        n = 1 + (i % 11)
        x = rng.normal(0, 1, (n, 8, 16)).astype(np.float32)
        deadline = eng._clock() - 1.0 if i % 10 == 9 else None
        out = eng.infer(x, deadline=deadline)        # must never raise
        if out is None:
            shed += 1
            continue
        served += 1
        ref = np.asarray(forward_sr(params, cfg, x))
        assert out.shape == (n, cfg.n_targets)
        assert np.isfinite(out).all()
        assert np.abs(out - ref).max() < 1e-3, f"request {i}"
    assert served == 27 and shed == 3
    c = eng.metrics.counters
    assert c["shed_requests"] == 3
    assert c["demotions"] >= 1 and c["probes"] >= 1
    assert c.get("promotions", 0) >= 1               # ladder healed itself
    assert inj.fired() >= 4                          # the drills really ran


def test_run_stream_demotes_on_compile_failure(jedi8):
    cfg, params, _, _ = jedi8
    inj = FaultInjector()
    inj.arm("compile", path="fused_full", times=math.inf)
    eng = _engine(jedi8, inj)
    stream = [np.random.RandomState(i).normal(0, 1, (8, 8, 16))
              .astype(np.float32) for i in range(4)]
    res = eng.run_stream(stream, warmup=1)
    assert len(res["latencies"]) == 3                # stream still served
    assert eng.active_path(eng.bucket_for(8)) == "sr_split"
    assert eng.metrics.counter("compile_failures") == 1


# -- health + registry contract ------------------------------------------


def test_health_snapshot_shape(jedi8):
    eng = _engine(jedi8)
    h = eng.health()
    assert h["state"] in ("healthy", "degraded", "shedding", "down")
    assert h["chain"] == ["fused_full", "sr_split"]
    assert h["base_path"] == "fused_full"
    assert isinstance(h["counters"], dict)


def test_resilient_engine_rejects_chain_without_terminal():
    cfg = JediNetConfig(n_objects=8, n_features=16)
    params = init(jax.random.PRNGKey(0), cfg, scale="lecun")
    spec = paths.get("fused_full")
    # a Pallas path whose chain dead-ends in itself must be refused
    paths.register(
        paths.PathSpec(name="_chaos_orphan", forward=spec.forward,
                       ref=spec.ref, fused_level="full", pallas=True),
        overwrite=True)
    try:
        with pytest.raises(ValueError, match="non-Pallas"):
            ResilientEngine(params, cfg, forward="_chaos_orphan",
                            interpret=True, max_batch=8)
    finally:
        paths._REGISTRY.pop("_chaos_orphan", None)


def test_drill_cli_serves_and_reports_health(capsys):
    from repro.launch import trigger_serve
    trigger_serve.main([
        "--forward", "fused_full", "--interpret", "--n-objects", "8",
        "--batch", "4", "--batches", "4", "--drill", "output_nan:99"])
    out = capsys.readouterr().out
    assert "DRILL" in out and "served=4" in out and "shed=0" in out
    assert "[health]" in out and "state=degraded" in out
    assert "demotions=1" in out and "nonfinite_batches=" in out


# -- silent seams: the gap, and the sentinel closing it ------------------


def test_silent_seams_invisible_without_sentinel(jedi8):
    """The gap proof: every silent seam strikes (finite, shaped, WRONG
    logits — deviation orders of magnitude past tolerance) yet no PR-6
    detector fires and ``health()`` keeps reading ``healthy``.  This is
    the blind spot :mod:`repro.serving.sentinel` exists for."""
    cfg, params, _, _ = jedi8
    rotation = list(zip(("scale_drift", "weight_corrupt", "stale_cache"),
                        (8, 16, 32)))
    inj = FaultInjector()
    for seam, bucket in rotation:
        inj.arm(seam, path="int8_fused_full", bucket=bucket, factor=8.0)
    eng = _engine(jedi8, inj, forward="int8_fused_full", max_batch=64)
    rng = np.random.RandomState(7)
    worst = 0.0
    for seam, bucket in rotation:
        for _ in range(4):                   # vary inputs: a stale-cache
            n = bucket - 3                   # replay is observably wrong
            x = rng.normal(0, 1, (n, 8, 16)).astype(np.float32)
            out = eng.infer(x)               # never raises
            assert out.shape == (n, cfg.n_targets)
            assert np.isfinite(out).all()
            ref = np.asarray(forward_sr(params, cfg, x))
            worst = max(worst, float(np.abs(out - ref).max()))
    assert worst > 1.0                       # the corruption is real...
    assert inj.fired() == 3                  # ...and every seam struck
    h = eng.health()
    assert h["state"] == "healthy"           # ...and the ladder is blind
    for k in ("compile_failures", "watchdog_timeouts", "nonfinite_batches",
              "dispatch_failures", "demotions", "quarantines"):
        assert k not in h["counters"], k


def test_rotating_silent_seams_detected_quarantined_recovered(jedi8):
    """The acceptance loop: the same rotation WITH the sentinel armed.
    Every silent seam is detected (first canary — one observed batch),
    quarantined, and recovered via clean-canary requalification, with
    zero exceptions and never a ``healthy`` report while the corrupted
    entry could serve."""
    from repro.serving import SentinelConfig

    cfg, params, _, _ = jedi8
    rotation = list(zip(("scale_drift", "weight_corrupt", "stale_cache"),
                        (8, 16, 32)))
    inj = FaultInjector()
    for seam, bucket in rotation:
        inj.arm(seam, path="int8_fused_full", bucket=bucket, times=1,
                factor=8.0)
    eng = _engine(jedi8, inj, forward="int8_fused_full", max_batch=64,
                  sentinel=SentinelConfig(canary_every=3, promote_after=2,
                                          shadow_rate=0.25,
                                          shadow_sync=True))
    rng = np.random.RandomState(11)
    for seam, bucket in rotation:
        n = bucket - 3
        states = []
        for _ in range(14):      # bounded: detect @1, requalify @~7
            x = rng.normal(0, 1, (n, 8, 16)).astype(np.float32)
            served_by = eng.active_path(bucket)   # pre-serve: quarantine
            out = eng.infer(x)               # never raises    # trips AFTER
            assert np.isfinite(out).all()
            if served_by != "int8_fused_full":
                # quarantined: the fp32 fallback serves CORRECT answers
                ref = np.asarray(forward_sr(params, cfg, x))
                assert np.abs(out - ref).max() < 1e-3
            states.append(eng.health()["state"])
        assert states[0] == "quarantined", seam      # 1-batch detection
        assert states[-1] == "healthy", seam         # ...and recovered
        first_ok = states.index("healthy")
        assert all(s == "quarantined" for s in states[:first_ok]), seam
    c = eng.metrics.counters
    assert c["quarantines"] == 3 and c["requalifications"] == 3
    assert c["sentinel_trips"] == 3 and c["canary_mismatches"] == 3
    assert inj.fired() == 3
    assert eng.health()["state"] == "healthy"
