"""Multi-device distribution tests, run in subprocesses with 8 fake CPU
devices (this process must keep seeing 1 device — see conftest note)."""

import os
import subprocess
import sys
import textwrap


def subprocess_env() -> dict:
    """Minimal env for test subprocesses.  JAX_PLATFORMS is passed through
    when set: without it a libtpu-equipped container spends 60+ s per
    subprocess probing for a TPU before falling back to CPU."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    if "JAX_PLATFORMS" in os.environ:
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    return env


def run_py(code: str, timeout=600) -> str:
    """Run code in a fresh python with 8 fake devices; return stdout."""
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(code))
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=timeout, env=subprocess_env(),
        cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """A pjit'd jedinet train step on a 4x2 mesh == unsharded step."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import interaction_net as inet
        from repro.training import make_optimizer, init_state, make_train_step
        from repro.training.schedule import constant
        from repro.parallel.sharding import axis_rules, train_state_shardings, batch_shardings

        cfg = inet.JediNetConfig(n_objects=8, n_features=4, fr_hidden=(8,),
                                 fo_hidden=(8,), phi_hidden=(8,))
        opt = make_optimizer("adamw", constant(1e-3))
        state = init_state(jax.random.PRNGKey(0), lambda k: inet.init(k, cfg), opt)
        step = make_train_step(lambda p, b: inet.loss_fn(p, cfg, b), opt)
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 8, 4))
        y = jnp.zeros((16,), jnp.int32)
        batch = {"x": x, "y": y}

        ref_state, ref_m = jax.jit(step)(state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh, axis_rules(mesh):
            st_sh = train_state_shardings(state, mesh)
            b_sh = batch_shardings(batch, mesh, {"x": ("batch", None, None),
                                                 "y": ("batch",)})
            f = jax.jit(step, in_shardings=(st_sh, b_sh),
                        out_shardings=(st_sh, None))
            got_state, got_m = f(state, batch)

        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(ref_state["params"]),
            jax.tree_util.tree_leaves(got_state["params"])))
        print("MAXERR", err)
        print("LOSSDIFF", abs(float(ref_m["loss"]) - float(got_m["loss"])))
    """)
    maxerr = float(out.split("MAXERR")[1].split()[0])
    lossdiff = float(out.split("LOSSDIFF")[1].split()[0])
    assert maxerr < 1e-4
    assert lossdiff < 1e-4


def test_ef_compressed_psum_convergence():
    """int8 error-feedback all-reduce: quantized DP training tracks exact
    DP training on a quadratic objective."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.parallel.sharding import shard_map_compat as shard_map
        from repro.training.grad_compression import ef_compressed_psum

        mesh = jax.make_mesh((8,), ("data",))
        target = jax.random.normal(jax.random.PRNGKey(0), (32,))

        def local_grad(w, xs):
            # per-shard quadratic losses with different data
            return jax.grad(lambda w_: jnp.mean((xs @ w_ - xs @ target) ** 2))(w)

        xs_all = jax.random.normal(jax.random.PRNGKey(1), (64, 32))

        @jax.jit
        @partial(shard_map, mesh=mesh, in_specs=(P(), P("data"), P("data")),
                 out_specs=(P(), P("data")))
        def compressed_step(w, xs, resid):
            g = local_grad(w, xs)
            gm, new_r = ef_compressed_psum({"g": g}, {"g": resid[0]}, "data")
            return gm["g"], new_r["g"][None, :]

        @jax.jit
        @partial(shard_map, mesh=mesh, in_specs=(P(), P("data")),
                 out_specs=P())
        def exact_step(w, xs):
            return jax.lax.pmean(local_grad(w, xs), "data")

        wq = jnp.zeros((32,)); we = jnp.zeros((32,))
        resid = jnp.zeros((8, 32))   # per-shard residual
        for i in range(80):
            ge = exact_step(we, xs_all); we = we - 0.1 * ge
            gq, resid = compressed_step(wq, xs_all, resid); wq = wq - 0.1 * gq
        print("EXACT_DIST", float(jnp.linalg.norm(we - target)))
        print("QUANT_DIST", float(jnp.linalg.norm(wq - target)))
    """)
    exact = float(out.split("EXACT_DIST")[1].split()[0])
    quant = float(out.split("QUANT_DIST")[1].split()[0])
    # |target| ~ sqrt(32) ~ 5.6 at init: both must have converged most of
    # the way, and error feedback must keep quantized DP tracking exact DP.
    assert exact < 1.0
    assert quant < 2.0 * exact + 0.1


def test_elastic_checkpoint_restore_across_meshes():
    """Save sharded on a (4,2) mesh, restore onto a (2,) mesh (pod loss)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.checkpoint import CheckpointManager
        from repro.parallel.sharding import param_shardings
        from jax.sharding import NamedSharding, PartitionSpec as P

        params = {"layers": {"attn": {"wq": {"w": jnp.arange(4*64*64, dtype=jnp.float32).reshape(4, 64, 64)}}}}
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sh_a = param_shardings(params, mesh_a)
        p_a = jax.tree_util.tree_map(jax.device_put, params,
                                     jax.tree_util.tree_map(lambda s: s, sh_a))
        with tempfile.TemporaryDirectory() as td:
            cm = CheckpointManager(td)
            cm.save(3, {"params": p_a, "step": jnp.asarray(3)})
            # "lose a pod": restore onto a smaller mesh
            mesh_b = jax.make_mesh((2,), ("data",))
            sh_b = param_shardings(params, mesh_b)
            restored, step = cm.restore(
                shardings={"params": sh_b, "step": None})
            w = restored["params"]["layers"]["attn"]["wq"]["w"]
            print("STEP", step)
            print("OK", bool(np.allclose(np.asarray(w), np.asarray(params["layers"]["attn"]["wq"]["w"]))))
            print("NSHARDS", len(w.sharding.device_set))
    """)
    assert "STEP 3" in out
    assert "OK True" in out
    assert "NSHARDS 2" in out


def test_train_driver_crash_restart():
    """Fault tolerance: injected crash at step 30, restart resumes from the
    step-25 checkpoint and finishes."""
    import tempfile, os
    with tempfile.TemporaryDirectory() as td:
        env = subprocess_env()
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", "jedinet-30p", "--steps", "60", "--batch", "32",
               "--ckpt-dir", td, "--ckpt-every", "25"]
        r1 = subprocess.run(cmd + ["--fail-at-step", "30"],
                            capture_output=True, text=True, timeout=600,
                            cwd="/root/repo", env=env)
        assert r1.returncode != 0
        assert "injected failure" in r1.stderr
        # checkpoint from step 25 must exist
        assert any(d.startswith("step_") for d in os.listdir(td))
        r2 = subprocess.run(cmd, capture_output=True, text=True,
                            timeout=600, cwd="/root/repo", env=env)
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "restored checkpoint at step 25" in r2.stdout
        assert "final checkpoint at step 60" in r2.stdout


def test_a2a_moe_dispatch_matches_global():
    """shard_map all-to-all MoE dispatch (§Perf cell B b3) == the global
    sort-based dispatch, bit-exact with ample capacity."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs.base import MoEConfig
        from repro.models import moe as moe_lib
        from repro.parallel.moe_dispatch import a2a_moe

        mesh = jax.make_mesh((8,), ("data",))
        moe = MoEConfig(n_experts=8, top_k=2, capacity_factor=8.0)
        params = moe_lib.init_moe(jax.random.PRNGKey(0), moe, 16, 32)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        ref, _ = moe_lib.moe_apply(params, moe, x,
                                   compute_dtype=jnp.float32)
        got = a2a_moe(x, params, moe, mesh)
        print("A2A_ERR", float(jnp.max(jnp.abs(ref - got))))
    """)
    assert float(out.split("A2A_ERR")[1].split()[0]) < 1e-5
