"""Per-kernel shape/dtype sweeps vs the pure-jnp ref.py oracles
(interpret=True executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import interaction_net as inet
from repro.kernels.fused_jedinet import ops as fj_ops
from repro.kernels.fused_jedinet.ref import fused_edge_block_ref
from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.fm_interaction import ops as fm_ops
from repro.kernels.fm_interaction.ref import fm_interaction_ref


# --- fused jedinet edge block ------------------------------------------------

@pytest.mark.parametrize("n_o,p,fr_hidden,d_e,batch", [
    (4, 3, (), 5, 4),             # no hidden layer (J-style NL=1 is (8,))
    (8, 6, (10,), 4, 6),
    (30, 16, (20, 20, 20), 8, 4),  # paper 30p
    (50, 16, (8, 8), 8, 2),        # paper U4
    (13, 5, (16, 12), 7, 8),       # odd sizes
])
def test_fused_edge_block_sweep(n_o, p, fr_hidden, d_e, batch):
    cfg = inet.JediNetConfig(n_objects=n_o, n_features=p, d_e=d_e,
                             fr_hidden=fr_hidden)
    params = inet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, n_o, p))
    ref = fused_edge_block_ref(params["fr"], cfg, x)
    got = fj_ops.fused_edge_block(params["fr"], cfg, x, interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_edge_block_dtypes(dtype):
    cfg = inet.JediNetConfig(n_objects=8, n_features=6, d_e=4,
                             fr_hidden=(10,))
    params = inet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 6)).astype(dtype)
    ref = fused_edge_block_ref(params["fr"], cfg, x.astype(jnp.float32))
    got = fj_ops.fused_edge_block(params["fr"], cfg, x, interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=tol, atol=tol)


def test_fused_edge_block_batch_tiling():
    """Different block_b tilings give identical results."""
    cfg = inet.JediNetConfig(n_objects=10, n_features=4, d_e=3,
                             fr_hidden=(8,))
    params = inet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, 10, 4))
    outs = [fj_ops.fused_edge_block(params["fr"], cfg, x, interpret=True,
                                    block_b=bb) for bb in (1, 3, 4, 12)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-6)


@pytest.mark.parametrize("batch", [7, 13])
def test_fused_edge_block_prime_batch(batch):
    """Prime / non-divisible batches pad to the tile instead of degrading
    the tile to block_b=1 (the old divisor-rule failure mode)."""
    cfg = inet.JediNetConfig(n_objects=10, n_features=4, d_e=3,
                             fr_hidden=(8,))
    params = inet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, 10, 4))
    ref = fused_edge_block_ref(params["fr"], cfg, x)
    # autotuned tile AND an explicit non-divisor tile both pad correctly
    for bb in (None, 4):
        got = fj_ops.fused_edge_block(params["fr"], cfg, x, interpret=True,
                                      block_b=bb)
        assert got.shape == (batch, 10, 3)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   rtol=2e-4, atol=2e-4)


# --- flash decode ------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,d,s,chunk", [
    (2, 4, 4, 32, 256, 64),       # MHA (G=1)
    (4, 8, 2, 64, 512, 128),      # GQA
    (1, 16, 1, 128, 1024, 256),   # MQA
])
def test_flash_decode_sweep(b, h, hkv, d, s, chunk):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    q_pos = jnp.asarray(np.random.RandomState(3).randint(1, s, b), jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    kv_pos = jnp.where(kv_pos <= q_pos[:, None], kv_pos, -1)
    got = fd_ops.flash_decode(q, k, v, q_pos, kv_pos, chunk=chunk,
                              interpret=True)
    scale = 1.0 / np.sqrt(d)
    ref = flash_decode_ref((q.astype(jnp.float32) * scale)
                           .reshape(b, hkv, h // hkv, d),
                           k, v, q_pos, kv_pos).reshape(b, h, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_sliding_window():
    b, h, hkv, d, s = 2, 4, 2, 32, 256
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    q_pos = jnp.asarray([200, 255], jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    got = fd_ops.flash_decode(q, k, v, q_pos, kv_pos, window=64, chunk=64,
                              interpret=True)
    scale = 1.0 / np.sqrt(d)
    ref = flash_decode_ref((q.astype(jnp.float32) * scale)
                           .reshape(b, hkv, h // hkv, d),
                           k, v, q_pos, kv_pos,
                           window=64).reshape(b, h, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_bf16_cache():
    """Serving caches are bf16; accumulation must stay fp32-stable."""
    b, h, hkv, d, s = 2, 4, 2, 32, 128
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    q_pos = jnp.full((b,), s - 1, jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    got = fd_ops.flash_decode(q, k.astype(jnp.bfloat16),
                              v.astype(jnp.bfloat16), q_pos, kv_pos,
                              chunk=64, interpret=True)
    scale = 1.0 / np.sqrt(d)
    ref = flash_decode_ref((q.astype(jnp.float32) * scale)
                           .reshape(b, hkv, h // hkv, d),
                           k, v, q_pos, kv_pos).reshape(b, h, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


# --- fm interaction ----------------------------------------------------------

@pytest.mark.parametrize("b,f,k", [(8, 5, 4), (16, 39, 10), (64, 26, 16)])
def test_fm_interaction_sweep(b, f, k):
    v = jax.random.normal(jax.random.PRNGKey(0), (b, f, k))
    got = fm_ops.fm_interaction(v, interpret=True)
    ref = fm_interaction_ref(v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_fm_interaction_equals_naive_pairwise():
    v = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 3))
    naive = sum(jnp.sum(v[:, i] * v[:, j], -1)
                for i in range(6) for j in range(i + 1, 6))
    got = fm_ops.fm_interaction(v, interpret=True)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(got),
                               rtol=1e-4, atol=1e-5)
