"""Serving subsystem: engine equivalence, batcher semantics, metrics,
bucket ladder, and the sharded (8 fake device) path."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.interaction_net import JediNetConfig, forward_sr, init
from repro.kernels.autotune import bucket_ladder, pick_block_b
from repro.serving import DeadlineBatcher, ServingEngine, ServingMetrics


@pytest.fixture(scope="module")
def jedi30():
    cfg = JediNetConfig(n_objects=30, n_features=16)
    params = init(jax.random.PRNGKey(0), cfg, scale="lecun")
    return cfg, params


@pytest.fixture(scope="module")
def engine30(jedi30):
    cfg, params = jedi30
    return ServingEngine(params, cfg, forward="fused_full", interpret=True,
                         max_batch=32)


# -- engine --------------------------------------------------------------


def test_engine_matches_sr_every_bucket(jedi30, engine30):
    """Acceptance: engine output == forward_sr to <1e-5 in fp32 for every
    bucket size, including non-bucket-aligned request counts (padding)."""
    cfg, params = jedi30
    rng = np.random.RandomState(0)
    for bucket in engine30.bucket_sizes:
        for n in (bucket, max(1, bucket - 3)):     # aligned + padded
            x = rng.normal(0, 1, (n, 30, 16)).astype(np.float32)
            got = engine30.infer(x)
            ref = np.asarray(forward_sr(params, cfg, jnp.asarray(x)))
            assert got.shape == (n, cfg.n_targets)
            assert np.abs(got - ref).max() < 1e-5, f"bucket={bucket} n={n}"


def test_engine_compile_cache_warm(jedi30, engine30):
    cfg, params = jedi30
    engine30.warm()
    n_compiled = engine30.cache_size
    assert n_compiled == len(engine30.bucket_sizes)
    # arbitrary request counts after warm() never add cache entries
    rng = np.random.RandomState(1)
    for n in (1, 5, 9, 17, 31):
        engine30.infer(rng.normal(0, 1, (n, 30, 16)).astype(np.float32))
    assert engine30.cache_size == n_compiled


def test_engine_chunks_oversized_requests(jedi30, engine30):
    cfg, params = jedi30
    top = engine30.bucket_sizes[-1]
    x = np.random.RandomState(2).normal(
        0, 1, (top + 7, 30, 16)).astype(np.float32)
    got = engine30.infer(x)
    ref = np.asarray(forward_sr(params, cfg, jnp.asarray(x)))
    assert got.shape[0] == top + 7
    assert np.abs(got - ref).max() < 1e-5


def test_engine_run_stream_pads_and_counts_valid_events(jedi30):
    cfg, params = jedi30
    eng = ServingEngine(params, cfg, forward="sr", max_batch=32)
    stream = [np.random.RandomState(i).normal(0, 1, (13, 30, 16))
              .astype(np.float32) for i in range(5)]
    res = eng.run_stream(stream, warmup=2)
    assert res["bucket"] == eng.bucket_for(13)
    assert len(res["latencies"]) == 3
    assert res["events"] == 3 * 13            # valid events, not padded rows
    snap = eng.metrics.snapshot()
    assert snap["events"] == 3 * 13
    assert snap["batches"] == 3


def test_engine_rejects_unknown_path(jedi30):
    cfg, params = jedi30
    with pytest.raises(ValueError):
        ServingEngine(params, cfg, forward="nope")


def test_engine_roofline_per_bucket(jedi30, engine30):
    roof = engine30.roofline()
    assert set(roof) == set(engine30.bucket_sizes)
    for b, m in roof.items():
        assert m["fused_level"] == "full"
        assert m["per_event_us"] == pytest.approx(m["step_us"] / b)
    # amortization: per-event cost never increases with bucket size
    # (tolerance for float wobble once the path turns compute-bound)
    per_event = [roof[b]["per_event_us"] for b in sorted(roof)]
    for smaller, larger in zip(per_event, per_event[1:]):
        assert larger <= smaller * (1 + 1e-9)


# -- bucket ladder -------------------------------------------------------


def test_bucket_ladder_covers_and_aligns():
    per_sample = 80_000                        # ~30p full-kernel working set
    for max_batch in (4, 8, 100, 256, 1009):
        ladder = bucket_ladder(max_batch, per_sample)
        assert ladder == sorted(set(ladder))
        assert ladder[-1] >= max_batch         # top rung covers max_batch
        tile = pick_block_b(max_batch, per_sample)
        for b in ladder:
            # every rung is budget-whole (one grid step) or a tile multiple
            assert b <= tile or b % tile == 0, (max_batch, tile, ladder)


def test_bucket_ladder_tiny_batch():
    assert bucket_ladder(1, 80_000) == [1]
    assert bucket_ladder(3, 80_000) == [3]


# -- batcher -------------------------------------------------------------


def test_batcher_flushes_on_full_bucket():
    bat = DeadlineBatcher([8, 16], deadline_s=1.0, clock=lambda: 0.0)
    x = np.zeros((6, 4, 2), np.float32)
    assert bat.submit(0, x, now=0.0) == []
    plans = bat.submit(1, x, now=0.0)          # 12 pending < 16
    assert plans == [] and bat.pending_events == 12
    plans = bat.submit(2, x, now=0.0)          # 18 >= 16: cut a full bucket
    assert len(plans) == 1
    (p,) = plans
    assert p.bucket == 16 and p.n_valid == 16 and p.reason == "full"
    assert [(r[0], r[2] - r[1]) for r in p.requests] == [(0, 6), (1, 6), (2, 4)]
    assert bat.pending_events == 2             # request 2's tail stays queued


def test_batcher_deadline_flush_and_bucket_choice():
    bat = DeadlineBatcher([8, 16], deadline_s=0.010, clock=lambda: 0.0)
    bat.submit(7, np.ones((5, 3), np.float32), now=1.000)
    assert bat.poll(now=1.005) == []           # deadline not reached
    plans = bat.poll(now=1.011)
    assert len(plans) == 1
    (p,) = plans
    assert p.reason == "deadline"
    assert p.bucket == 8                       # smallest rung holding 5
    assert p.n_valid == 5
    assert p.oldest_wait_s == pytest.approx(0.011)
    assert bat.pending_events == 0
    assert bat.poll(now=2.0) == []             # empty queue never flushes


def test_batcher_forced_flush_chunks_backlog():
    bat = DeadlineBatcher([8], deadline_s=10.0, clock=lambda: 0.0)
    bat.submit(0, np.ones((3, 2), np.float32), now=0.0)
    # 12 pending >= bucket 8: submit cuts the full bucket immediately
    plans = bat.submit(1, np.ones((9, 2), np.float32), now=0.0)
    assert [p.n_valid for p in plans] == [8]
    assert plans[0].reason == "full"
    plans += bat.flush(now=0.0)                # remaining 4 forced out
    assert [p.n_valid for p in plans] == [8, 4]
    assert plans[1].reason == "forced"
    # request 1 straddles both plans; segments reassemble to 9 events
    seg_events = sum(stop - start for p in plans
                     for rid, start, stop in p.requests if rid == 1)
    assert seg_events == 9


def test_batcher_run_plan_reassembles_per_request(jedi30, engine30):
    cfg, params = jedi30
    bat = DeadlineBatcher(engine30.bucket_sizes, deadline_s=1.0,
                          clock=lambda: 0.0)
    rng = np.random.RandomState(3)
    xs = {rid: rng.normal(0, 1, (n, 30, 16)).astype(np.float32)
          for rid, n in ((10, 3), (11, 5), (12, 2))}
    for rid, x in xs.items():
        bat.submit(rid, x, now=0.0)
    (plan,) = bat.flush(now=0.0)
    results = engine30.run_plan(plan)
    assert set(results) == set(xs)
    for rid, x in xs.items():
        ref = np.asarray(forward_sr(params, cfg, jnp.asarray(x)))
        assert results[rid].shape == (x.shape[0], cfg.n_targets)
        assert np.abs(results[rid] - ref).max() < 1e-5


def test_batcher_rejects_empty_request():
    bat = DeadlineBatcher([8])
    with pytest.raises(ValueError):
        bat.submit(0, np.zeros((0, 2), np.float32))


def test_batcher_full_bucket_and_deadline_same_tick_flush_once():
    """Race corner: a submission that fills the bucket at the exact tick
    the oldest request's deadline expires must flush exactly once — the
    full-bucket cut wins, and the same-tick poll sees an empty queue
    instead of re-flushing the same events."""
    bat = DeadlineBatcher([8], deadline_s=0.010, clock=lambda: 0.0)
    bat.submit(0, np.ones((4, 2), np.float32), now=1.000)
    # t = 1.010: deadline expired AND this submission reaches 8 events
    plans = bat.submit(1, np.ones((4, 2), np.float32), now=1.010)
    assert [p.n_valid for p in plans] == [8]
    assert plans[0].reason == "full"
    assert bat.pending_events == 0
    assert bat.poll(now=1.010) == []           # nothing left to re-flush
    # every event landed in exactly one plan
    segs = [(rid, stop - start) for p in plans
            for rid, start, stop in p.requests]
    assert segs == [(0, 4), (1, 4)]


def test_batcher_full_cut_tail_keeps_its_own_deadline():
    """When the same-tick cut leaves a tail (the filling request
    straddles the bucket), the tail is NOT double-flushed at that tick —
    it waits on its own submit-time fuse and drains exactly once when
    THAT expires."""
    bat = DeadlineBatcher([8], deadline_s=0.010, clock=lambda: 0.0)
    bat.submit(0, np.ones((4, 2), np.float32), now=1.000)
    plans = bat.submit(1, np.ones((7, 2), np.float32), now=1.010)
    assert [p.n_valid for p in plans] == [8] and bat.pending_events == 3
    assert bat.poll(now=1.010) == []           # tail submitted at 1.010:
    plans += bat.poll(now=1.020)               # its fuse burns at 1.020
    assert [p.n_valid for p in plans] == [8, 3]
    assert plans[1].reason == "deadline"
    assert bat.poll(now=1.020) == []
    assert sum(stop - start for p in plans
               for rid, start, stop in p.requests if rid == 1) == 7


def test_batcher_zero_deadline_flushes_on_first_poll():
    """deadline_s=0 means "never hold a request": the poll at the very
    same tick as the submission flushes it."""
    bat = DeadlineBatcher([8], deadline_s=0.0, clock=lambda: 0.0)
    bat.submit(0, np.ones((2, 2), np.float32), now=5.0)
    (plan,) = bat.poll(now=5.0)
    assert plan.n_valid == 2 and plan.reason == "deadline"
    assert plan.oldest_wait_s == 0.0


def test_batcher_negative_deadline_flushes_immediately():
    """A negative budget (clock skew, already-late request) must behave
    like zero — flush on the next poll, not wedge the queue forever."""
    bat = DeadlineBatcher([8], deadline_s=-1.0, clock=lambda: 0.0)
    bat.submit(0, np.ones((3, 2), np.float32), now=2.0)
    (plan,) = bat.poll(now=2.0)
    assert plan.n_valid == 3 and plan.reason == "deadline"


# -- metrics -------------------------------------------------------------


def test_metrics_snapshot_accounting():
    m = ServingMetrics()
    for lat_ms in (1.0, 2.0, 3.0, 4.0):
        m.record_batch(lat_ms * 1e-3, events=10, bucket=16)
    m.record_wall(0.01, 40)
    snap = m.snapshot()
    assert snap["batches"] == 4 and snap["events"] == 40
    assert snap["p50_us"] == pytest.approx(2500.0)
    assert snap["per_event_p50_us"] == pytest.approx(250.0)
    assert snap["kgps"] == pytest.approx(4.0)   # 40 events / 10 ms
    assert snap["buckets"] == [16]


def test_metrics_empty_snapshot_is_nan_not_crash():
    snap = ServingMetrics().snapshot()
    assert snap["batches"] == 0 and snap["events"] == 0
    assert np.isnan(snap["p50_us"]) and np.isnan(snap["kgps"])
    assert snap["gauges"] == {}


def test_metrics_gauges_replace_and_track_peak():
    m = ServingMetrics()
    m.gauge("queue_depth", 3)
    m.gauge("queue_depth", 7)
    m.gauge("queue_depth", 1)           # gauges REPLACE, unlike counters
    assert m.gauge_value("queue_depth") == 1
    assert m.gauge_max("queue_depth") == 7
    assert m.gauge_value("missing", default=-1.0) == -1.0
    assert m.gauge_max("missing") == 0.0
    m.gauge("inflight", 2)
    snap = m.snapshot()
    assert snap["gauges"] == {"inflight": 2.0, "queue_depth": 1.0}


# -- sharded path (subprocess with 8 fake CPU devices) -------------------


def test_engine_shards_batch_axis_over_mesh():
    """Engine shard_maps the batch axis over the host mesh and still
    matches forward_sr — for the XLA path and the fused Pallas path."""
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.interaction_net import JediNetConfig, init, forward_sr
        from repro.serving import ServingEngine

        cfg = JediNetConfig(n_objects=30, n_features=16)
        params = init(jax.random.PRNGKey(0), cfg, scale="lecun")
        x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (100, 30, 16)))
        ref = np.asarray(forward_sr(params, cfg, jnp.asarray(x)))
        # max_batch=100 does not divide the 8-way mesh: the per-device
        # ladder must round UP so the top bucket still covers it
        for fwd, n, mb in (("sr_split", 100, 100), ("fused_full", 20, 64)):
            eng = ServingEngine(params, cfg, forward=fwd, max_batch=mb)
            assert eng.n_shards == 8, eng.n_shards
            assert all(b % 8 == 0 for b in eng.bucket_sizes)
            assert eng.bucket_sizes[-1] >= mb, eng.bucket_sizes
            err = np.abs(eng.infer(x[:n]) - ref[:n]).max()
            print(fwd.upper() + "_ERR", err)
    """))
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    if "JAX_PLATFORMS" in os.environ:   # skip the 60s TPU probe off-TPU
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=600, env=env, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    assert float(out.stdout.split("SR_SPLIT_ERR")[1].split()[0]) < 1e-5
    assert float(out.stdout.split("FUSED_FULL_ERR")[1].split()[0]) < 1e-5
