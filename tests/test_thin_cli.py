"""AST guard: launch CLIs stay thin shells over the serving package.

The PR that unified the serving fabric moved every behavior out of
``repro.launch.trigger_serve`` and ``repro.launch.serve`` into
``repro.serving.*``; these tests keep it that way.  A thin CLI module
may contain ONLY: a docstring, imports, simple constant assignments, a
``main`` function, and the ``if __name__ == "__main__"`` block — and
``main`` itself may only build an argparse parser and call into
``repro.serving``.  No loops, no classes, no numerics imports: if a
change needs any of those, it belongs behind the serving package where
the event loop, the benchmarks and the tests can reuse it.
"""

import ast
import pathlib

import pytest

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
THIN_CLIS = ("repro/launch/trigger_serve.py", "repro/launch/serve.py")

# engine/batching logic needs numerics; a thin shell must not
FORBIDDEN_IMPORTS = ("jax", "numpy", "jax.numpy")
# the only package a thin CLI may reach into (argparse etc. are stdlib)
ALLOWED_REPRO_PREFIX = "repro.serving"


def _tree(rel):
    path = SRC / rel
    return ast.parse(path.read_text(), filename=str(path))


def _imported_modules(tree):
    mods = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            mods.extend(a.name for a in node.names)
        elif isinstance(node, ast.ImportFrom):
            mods.append(node.module or "")
    return mods


@pytest.mark.parametrize("rel", THIN_CLIS)
def test_cli_top_level_shape(rel):
    """Top level: docstring, imports, constants, main(), __main__ guard."""
    tree = _tree(rel)
    for i, node in enumerate(tree.body):
        if i == 0 and isinstance(node, ast.Expr):
            continue                    # module docstring
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            continue
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue                    # simple module constants
        if isinstance(node, ast.FunctionDef) and node.name == "main":
            continue
        if isinstance(node, ast.If):    # if __name__ == "__main__": main()
            cond = ast.unparse(node.test)
            assert "__name__" in cond, (
                f"{rel}: top-level `if {cond}` — only the __main__ guard "
                "is allowed")
            continue
        pytest.fail(
            f"{rel}:{node.lineno}: top-level {type(node).__name__} — thin "
            "CLI modules hold only imports, constants, main() and the "
            "__main__ guard; move logic into repro.serving")


@pytest.mark.parametrize("rel", THIN_CLIS)
def test_cli_main_has_no_logic(rel):
    """main() may parse args and delegate — no loops/branches/defs."""
    tree = _tree(rel)
    main = next(n for n in tree.body
                if isinstance(n, ast.FunctionDef) and n.name == "main")
    for node in ast.walk(main):
        if node is main:
            continue
        assert not isinstance(
            node, (ast.For, ast.While, ast.FunctionDef, ast.AsyncFunctionDef,
                   ast.ClassDef, ast.Try, ast.With)), (
            f"{rel}:{node.lineno}: {type(node).__name__} inside main() — "
            "batching/serving logic belongs in repro.serving")
        if node is not main and isinstance(node, ast.If):
            pytest.fail(
                f"{rel}:{node.lineno}: branch inside main() — routing "
                "decisions belong in repro.serving")


@pytest.mark.parametrize("rel", THIN_CLIS)
def test_cli_imports_only_serving(rel):
    """No numerics, and no repro package other than repro.serving."""
    for mod in _imported_modules(_tree(rel)):
        root = mod.split(".")[0]
        assert root not in FORBIDDEN_IMPORTS, (
            f"{rel}: imports {mod!r} — a thin CLI has no numerics")
        if root == "repro":
            assert mod == "repro.serving" or mod.startswith(
                "repro.serving."), (
                f"{rel}: imports {mod!r} — thin CLIs reach the framework "
                f"only through {ALLOWED_REPRO_PREFIX}")


@pytest.mark.parametrize("rel", THIN_CLIS)
def test_cli_still_defines_main(rel):
    """The shells stay runnable: a main() and a __main__ guard exist."""
    tree = _tree(rel)
    names = [n.name for n in tree.body if isinstance(n, ast.FunctionDef)]
    assert names == ["main"]
    assert any(isinstance(n, ast.If) for n in tree.body)
