"""Launch CLIs stay thin shells — now enforced by the lint framework.

The AST guard that lived here moved into
``repro.analysis.rules.thin_cli`` (rule id ``thin-cli``) so the same
check runs in `python -m repro.analysis`, CI, and here; this test is
the thin tier-1 assertion that the rule reports zero findings on the
repo, plus a sanity check that the rule still BITES (a deliberately
fat CLI must be flagged — a silently dead guard is worse than none).
"""

import pathlib

from repro.analysis.config import AnalysisConfig
from repro.analysis.lint import LintContext, run_lint
from repro.analysis.rules.thin_cli import ThinCliRule

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_thin_clis_report_zero_findings():
    findings = run_lint(REPO, [ThinCliRule()], AnalysisConfig.load(REPO))
    assert findings == [], "\n".join(f.render() for f in findings)


def test_rule_bites_on_a_fat_cli(tmp_path):
    (tmp_path / "fat_cli.py").write_text(
        '"""doc."""\n'
        "import jax\n"
        "from repro.core import paths\n"
        "def helper():\n"
        "    pass\n"
        "def main():\n"
        "    for i in range(3):\n"
        "        print(i)\n"
        'if __name__ == "__main__":\n'
        "    main()\n")
    config = AnalysisConfig()
    config.options["thin-cli"] = {"paths": ["fat_cli.py"]}
    findings = run_lint(tmp_path, [ThinCliRule()], config)
    messages = "\n".join(f.render() for f in findings)
    assert "imports 'jax'" in messages
    assert "imports 'repro.core'" in messages
    assert "top-level def helper()" in messages
    assert "For inside main()" in messages
