"""Sender-tiled whole-network kernel: (block_b, block_s) corner-case
numerics, in-kernel int8 dequant vs the HBM-boundary scheme, the 2D
working-set autotuner, the quantization-aware bucket policy, and the
large-graph (N_o=128) regime the untiled kernel's model rejects."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import interaction_net as inet
from repro.core import paths
from repro.core.int8_path import dequantize_params, quantize_params_int8
from repro.data.jets import TRACKS_N, make_jets, make_tracks
from repro.kernels import autotune as shared_autotune
from repro.kernels.fused_jedinet import autotune
from repro.kernels.fused_jedinet import full_kernel as FK
from repro.kernels.fused_jedinet import ops as fj_ops


def _setup(n_o, fr_hidden, fo_hidden, batch, **cfg_kw):
    cfg = inet.JediNetConfig(n_objects=n_o, n_features=16,
                             fr_hidden=fr_hidden, fo_hidden=fo_hidden,
                             **cfg_kw)
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    x, _ = make_jets(np.random.RandomState(1), batch, n_o)
    return cfg, params, jnp.asarray(x)


# --- (block_b, block_s) corner-case numerics vs the spec reference ----------


@pytest.mark.parametrize("block_s", [
    5,       # block_s ∤ N_o: remainder sender tile, bounds mask live
    8,       # sublane tile, 13 = 8 + 5 remainder
    13,      # block_s == N_o: degenerate single sender step (old kernel)
    16,      # block_s > N_o: clamped to N_o
])
@pytest.mark.parametrize("block_b", [1, 3, 4])
def test_tiled_matches_reference_across_corner_tiles(block_s, block_b):
    """Every (block_b, block_s) combination — remainder sender tiles,
    degenerate full-axis tiles, non-dividing batch tiles — matches the
    path's declared reference within its declared tolerance."""
    spec = paths.get("fused_full")
    cfg, params, x = _setup(13, (16, 12), (10,), 7)
    ref = spec.ref(params, cfg, x)
    out = fj_ops.fused_forward_full(params, cfg, x, interpret=True,
                                    block_b=block_b, block_s=block_s)
    err = float(jnp.max(jnp.abs(ref - out)))
    assert err < spec.tolerance, (block_b, block_s, err)


@pytest.mark.parametrize("batch", [1, 3, 7, 11])
def test_tiled_prime_batches_with_sender_remainder(batch):
    """Prime batches (padded batch tiles) x non-dividing sender tiles."""
    spec = paths.get("fused_full")
    cfg, params, x = _setup(30, (20, 20, 20), (20, 20, 20), batch)
    ref = spec.ref(params, cfg, x)
    out = fj_ops.fused_forward_full(params, cfg, x, interpret=True,
                                    block_b=4, block_s=8)   # 30 = 3*8 + 6
    assert out.shape == (batch, cfg.n_targets)
    err = float(jnp.max(jnp.abs(ref - out)))
    assert err < spec.tolerance, (batch, err)


def test_block_s_degenerate_equals_untiled_summand_order():
    """block_s = N_o is ONE sender step — bitwise the old untiled kernel
    (same mask, same single-chunk accumulation); other tilings agree to
    fp32 reassociation noise only."""
    cfg, params, x = _setup(13, (16, 12), (10,), 4)
    full = fj_ops.fused_forward_full(params, cfg, x, interpret=True,
                                     block_b=4, block_s=13)
    for bs in (5, 8):
        tiled = fj_ops.fused_forward_full(params, cfg, x, interpret=True,
                                          block_b=4, block_s=bs)
        np.testing.assert_allclose(np.asarray(full), np.asarray(tiled),
                                   rtol=1e-5, atol=1e-6)


def test_tiled_bf16_compute_dtype_threads_through():
    cfg, params, x = _setup(13, (16, 12), (10,), 4)
    fp32 = fj_ops.fused_forward_full(params, cfg, x, interpret=True,
                                     block_s=5)
    bcfg = cfg.with_(compute_dtype="bfloat16")
    bf16 = fj_ops.fused_forward_full(params, bcfg, x, interpret=True,
                                     block_s=5)
    assert bf16.dtype == jnp.float32
    err = float(jnp.max(jnp.abs(fp32 - bf16)))
    scale = float(jnp.max(jnp.abs(fp32)))
    assert 0.0 < err < 5e-2 * max(scale, 1.0), (err, scale)


def test_unpadded_batch_raises_with_tile_and_vmem_context():
    """The kernel-call guard names the chosen (block_b, block_s) and the
    modeled VMEM bytes — not the bare (bsz, block_b) tuple — so a caller
    that skipped autotune.pad_batch sees what to pad to and why."""
    cfg, params, x = _setup(13, (16, 12), (10,), 7)
    cdt = jnp.dtype(cfg.compute_dtype)
    from repro.kernels.fused_jedinet import kernel as K
    fr = K.split_first_layer(params["fr"], cfg.n_features, dtype=cdt)
    with pytest.raises(ValueError) as ei:
        FK.fused_forward_full_kernel_call(
            x.astype(cdt), [fr[0], fr[1], fr[2], *fr[3]],
            FK.flatten_mlp(params["fo"], cdt),
            FK.flatten_mlp(params["phi"], cdt),
            activation=cfg.activation, n_targets=cfg.n_targets,
            block_b=4, block_s=5, interpret=True)
    msg = str(ei.value)
    assert "block_b=4" in msg and "block_s=5" in msg
    assert "VMEM" in msg and "pad_batch" in msg


# --- int8: in-kernel dequant vs the PR-4 HBM-boundary scheme ----------------


@pytest.fixture(scope="module")
def qsetup():
    cfg = inet.JediNetConfig(n_objects=13, n_features=16,
                             fr_hidden=(16, 12), fo_hidden=(10,))
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    x, _ = make_jets(np.random.RandomState(1), 5, 13)
    return cfg, quantize_params_int8(params), jnp.asarray(x)


def test_int8_weights_reach_the_kernel_as_int8(qsetup):
    """The quantized params are passed VERBATIM: flatten/split keep the
    int8 dtype all the way to the kernel operands (1 B/element HBM)."""
    cfg, qp, _ = qsetup
    from repro.kernels.fused_jedinet import kernel as K
    fr = K.split_first_layer(qp["fr"], cfg.n_features, dtype=jnp.float32)
    assert fr[0].dtype == jnp.int8 and fr[1].dtype == jnp.int8
    flat = FK.flatten_mlp(qp["fo"], jnp.float32)
    assert flat[0].dtype == jnp.int8          # weight stays int8
    assert flat[1].dtype == jnp.float32       # bias stays fp32
    assert fj_ops.is_quantized_params(qp)


@pytest.mark.parametrize("block_s", [5, 13])
def test_int8_in_kernel_matches_hbm_boundary_dequant(qsetup, block_s):
    """In-kernel dequant ((h @ W_q) * scale on the fp32 accumulator) vs
    the PR-4 scheme (dequantize at the HBM boundary, kernel sees fp32
    weights): same quantized weights, fp32-reassociation-level agreement
    — and both within the spec tolerance of the XLA reference."""
    cfg, qp, x = qsetup
    spec = paths.get("int8_fused_full")
    in_kernel = fj_ops.fused_forward_full(qp, cfg, x, interpret=True,
                                          block_s=block_s)
    boundary = fj_ops.fused_forward_full(dequantize_params(qp), cfg, x,
                                         interpret=True, block_s=block_s)
    np.testing.assert_allclose(np.asarray(in_kernel), np.asarray(boundary),
                               rtol=1e-4, atol=1e-5)
    ref = spec.ref(qp, cfg, x)
    assert float(jnp.max(jnp.abs(in_kernel - ref))) < spec.tolerance


def test_partially_quantized_params_rejected_at_boundary(qsetup):
    """Mixed fp32/int8 pytrees would push fp32 weights through the int8
    scale plumbing — the wrapper rejects them with a clear error."""
    cfg, qp, x = qsetup
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    mixed = {"fr": qp["fr"], "fo": params["fo"], "phi": params["phi"]}
    with pytest.raises(ValueError, match="partially quantized"):
        fj_ops.is_quantized_params(mixed)
    with pytest.raises(ValueError, match="partially quantized"):
        fj_ops.fused_forward_full(mixed, cfg, x, interpret=True)


def test_edge_kernel_rejects_quantized_params(qsetup):
    """The edge-only kernel has no scale plumbing — int8 params must be
    rejected at the boundary, not matmul'd unscaled."""
    cfg, qp, x = qsetup
    with pytest.raises(ValueError, match="fused_forward_full"):
        fj_ops.fused_edge_block(qp["fr"], cfg, x, interpret=True)


def test_int8_path_forward_skips_fp32_materialization(qsetup):
    """The registered path hands the int8 pytree straight to the fused
    wrapper and still meets its tolerance end to end."""
    cfg, qp, x = qsetup
    spec = paths.get("int8_fused_full")
    out = spec.forward(qp, cfg, x, interpret=True)
    err = float(jnp.max(jnp.abs(out - spec.ref(qp, cfg, x))))
    assert err < spec.tolerance


# --- 2D autotuner -----------------------------------------------------------


def _w50():
    return [20, 20, 20, 8], [20, 20, 20, 24], [20, 20, 20, 5]


def test_tiled_live_set_shrinks_with_block_s():
    fr, fo, phi = _w50()
    per = [autotune.full_forward_tiled_bytes_per_sample(50, 16, fr, fo, phi,
                                                        bs)
           for bs in (8, 16, 50)]
    assert per[0] < per[1] < per[2]
    # block_s = N_o reproduces the untiled estimate exactly
    assert per[2] == autotune.full_forward_bytes_per_sample(50, 16, fr, fo,
                                                            phi)


def test_pick_block_b_s_grows_block_b_at_50p():
    """The sender-tiled live set buys >= 1.2x the untiled batch tile at
    N_o=50 (the PR's acceptance ratio; actual gain is ~4x)."""
    fr, fo, phi = _w50()
    untiled_bb = autotune.pick_block_b(
        1024, autotune.full_forward_bytes_per_sample(50, 16, fr, fo, phi))
    bb, bs = autotune.pick_block_b_s(1024, 50, 16, fr, fo, phi)
    assert bs < 50
    assert bb >= 1.2 * untiled_bb, (bb, untiled_bb)


def test_pick_block_b_s_degenerates_to_untiled_for_small_batches():
    """When the whole batch fits at every sender tile, ties break to
    block_s = N_o — zero sender-loop overhead, the old kernel."""
    fr, fo, phi = _w50()
    bb, bs = autotune.pick_block_b_s(4, 50, 16, fr, fo, phi)
    assert (bb, bs) == (4, 50)


def test_sender_tile_candidates_cover_remainders():
    assert autotune.sender_tile_candidates(50) == [8, 16, 32, 50]
    assert autotune.sender_tile_candidates(128) == [8, 16, 32, 64, 128]
    assert autotune.sender_tile_candidates(5) == [5]


@pytest.mark.parametrize("batch", [1, 2, 4])
def test_pick_block_b_s_never_returns_a_non_fitting_tile(batch):
    """At tiny batches every sender tile ties at block_b = batch, and the
    larger-block_s tie-break used to hand back the UNTILED candidate —
    whose single-sample working set busts the budget on large graphs
    (would OOM VMEM on real hardware; interpret mode hides it).  The
    picker must only tie-break among candidates that actually fit."""
    fr, fo, phi = [128, 128, 8], [64, 64, 24], [32, 32, 5]
    bb, bs = autotune.pick_block_b_s(batch, 128, 16, fr, fo, phi)
    per = autotune.full_forward_tiled_bytes_per_sample(128, 16, fr, fo, phi,
                                                       bs)
    assert autotune.fits_vmem(per)
    assert bb * per <= autotune.VMEM_BUDGET_BYTES


def test_pick_block_s_fits_beside_pinned_block_b():
    """The one-knob-pinned complement: pinning block_b must tune block_s
    under it (and vice versa via the wrapper), never reuse a partner
    jointly tuned for a different tile."""
    fr, fo, phi = [128, 128, 8], [64, 64, 24], [32, 32, 5]
    for bb in (1, 4, 12):
        bs = autotune.pick_block_s(bb, 128, 16, fr, fo, phi)
        per = autotune.full_forward_tiled_bytes_per_sample(128, 16, fr, fo,
                                                           phi, bs)
        assert bb * per <= autotune.VMEM_BUDGET_BYTES, (bb, bs)
    # an OVERSUBSCRIBED pinned block_b (no sender tile fits beside it)
    # degrades to the smallest live set rather than a larger one
    assert autotune.pick_block_s(1000, 128, 16, fr, fo, phi) == \
        autotune.sender_tile_candidates(128)[0]
    # small graphs: a tiny pinned block_b affords the untiled degenerate
    assert autotune.pick_block_s(1, 30, 16, *_w50()) == 30


def test_untiled_model_rejects_large_graphs_tiled_fits():
    """N_o=128 with f_R width 128: the untiled grid exceeds the VMEM
    budget for a SINGLE sample; the tiled model fits with a real tile."""
    fr, fo, phi = [128, 128, 8], [64, 24], [32, 5]
    untiled = autotune.full_forward_bytes_per_sample(128, 16, fr, fo, phi)
    assert not autotune.fits_vmem(untiled)
    bb, bs = autotune.pick_block_b_s(64, 128, 16, fr, fo, phi)
    tiled = autotune.full_forward_tiled_bytes_per_sample(128, 16, fr, fo,
                                                         phi, bs)
    assert autotune.fits_vmem(tiled)
    assert bb > 1


def test_reserved_bytes_shrink_the_tile():
    fr, fo, phi = _w50()
    bb_free, _ = autotune.pick_block_b_s(1024, 50, 16, fr, fo, phi)
    bb_res, _ = autotune.pick_block_b_s(1024, 50, 16, fr, fo, phi,
                                        reserved_bytes=4 * 2**20)
    assert bb_res < bb_free


# --- quantization-aware bucket policy ---------------------------------------


def test_weight_vmem_bytes_counts_actual_dtypes():
    cfg = inet.JediNetConfig(n_objects=16, n_features=16)
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    fp = shared_autotune.weight_vmem_bytes(params)
    q = shared_autotune.weight_vmem_bytes(quantize_params_int8(params))
    assert 0 < q < fp
    # int8 weights + fp32 biases/scales: well under half the fp32 bill
    assert q < 0.5 * fp
    # fp weights bill at the SHIPPED dtype: bf16 compute halves the
    # weight share (biases stay fp32), int weights are verbatim
    bf16 = shared_autotune.weight_vmem_bytes(params, "bfloat16")
    assert q < bf16 < fp
    assert shared_autotune.weight_vmem_bytes(
        quantize_params_int8(params), "float32") == q


def test_quantized_path_earns_deeper_ladder_when_weights_dominate():
    """With weights big enough to matter against the VMEM budget, the
    int8 path's smaller reservation yields a strictly deeper ladder
    than the fp32 twin's — the per-path policy, resolved through the
    same spec.bucket_ladder the engine uses."""
    cfg = inet.JediNetConfig(n_objects=50, n_features=16,
                             fr_hidden=(256, 256), fo_hidden=(512, 512),
                             phi_hidden=(512, 512))
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    fp_spec, q_spec = paths.get("fused_full"), paths.get("int8_fused_full")
    qparams = q_spec.prepare_params(params)
    fp_ladder = fp_spec.bucket_ladder(cfg, params, 4096)
    q_ladder = q_spec.bucket_ladder(cfg, qparams, 4096)
    assert q_spec.reserved_vmem_bytes(cfg, qparams) < \
        fp_spec.reserved_vmem_bytes(cfg, params)
    # same per-sample model, smaller reservation -> larger VMEM tile:
    # the first rung past the sublane doublings IS the tile
    assert q_ladder != fp_ladder
    assert q_ladder[1] > fp_ladder[1]
    # rung-for-rung the quantized ladder is at least as deep (the final
    # rung is max_batch padded to the tile, so it is excluded)
    for q_b, fp_b in zip(q_ladder[:-1], fp_ladder[:-1]):
        assert q_b >= fp_b


def test_path_bucket_policy_surface():
    """codesign.path_bucket_policy is the one-stop operator view: ladder,
    VMEM model, reservation and per-rung roofline all from the spec."""
    from repro.core import codesign
    cfg = inet.JediNetConfig(n_objects=30, n_features=16)
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    pol = codesign.path_bucket_policy(paths.get("int8_fused_full"), cfg,
                                      params, max_batch=64)
    assert pol["path"] == "int8_fused_full"
    assert pol["weight_bytes"] == 1
    assert pol["bucket_ladder"] == sorted(pol["bucket_ladder"])
    assert set(pol["roofline"]) == set(pol["bucket_ladder"])
    assert pol["reserved_vmem_bytes"] > 0
    for m in pol["roofline"].values():
        assert m["weight_bytes"] == 1


def test_describe_with_cfg_prints_resolved_policy():
    cfg = inet.JediNetConfig(n_objects=16, n_features=16)
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    table = paths.describe(cfg=cfg, params=params, max_batch=32)
    assert "bucket policy" in table and "ladder" in table
    assert "reservedB" in table
    for n in paths.available():
        assert table.count(n) >= 2        # static row + policy row


def test_trigger_serve_list_paths_prints_policy(capsys):
    from repro.launch import trigger_serve
    trigger_serve.main(["--list-paths", "--n-objects", "16", "--batch", "32"])
    out = capsys.readouterr().out
    assert "wB" in out                     # weight-bytes column
    assert "float32" in out                # compute dtypes
    assert "bucket policy" in out and "ladder" in out
    assert "int8_fused_full" in out


# --- large-graph regime (N_o=128 tracks) ------------------------------------


def test_make_tracks_shapes_and_classes():
    x, y = make_tracks(np.random.RandomState(0), 6)
    assert x.shape == (6, TRACKS_N, 16) and x.dtype == np.float32
    assert y.shape == (6,) and set(np.unique(y)) <= set(range(5))
    assert np.isfinite(x).all()


def test_tracks128_runs_through_tiled_kernel_only():
    """The registered large-graph config: untiled model rejects even one
    sample, the tiled kernel serves it (interpret mode on CPU) and
    matches the XLA reference."""
    from repro.configs.jedi_tracks_128 import MODEL as cfg
    widths = ([*cfg.fr_hidden, cfg.d_e], [*cfg.fo_hidden, cfg.d_o],
              [*cfg.phi_hidden, cfg.n_targets])
    untiled = autotune.full_forward_bytes_per_sample(
        cfg.n_objects, cfg.n_features, *widths)
    assert not autotune.fits_vmem(untiled)

    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    x, _ = make_tracks(np.random.RandomState(1), 3)
    x = jnp.asarray(x)
    spec = paths.get("fused_full")
    out = fj_ops.fused_forward_full(params, cfg, x, interpret=True)
    ref = spec.ref(params, cfg, x)
    assert out.shape == (3, cfg.n_targets)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < spec.tolerance, err
