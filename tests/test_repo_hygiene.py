"""Repo hygiene: no bytecode artifacts, no resurrected legacy API names.

A tracked ``__pycache__`` directory once shadowed a real package at
import time (``src/repro/serving/__pycache__`` survived a refactor and
Python happily imported the stale ``.pyc``s) — the failure mode is
silent and maddening, so tier-1 fails fast on any tracked bytecode and
on a ``.gitignore`` that stopped covering it.  CI runs the same check
shell-side in the lint job; this test makes it bite locally too.

The legacy-name guard keeps the retired pre-registry forward-path
surfaces (the flat forward-fn mapping on ``interaction_net`` and the
lazy path-name snapshots on the serving package) from creeping back in
via copy-paste from old branches: the registry
(``repro.core.paths``) is the one forward-path API.
"""

import pathlib
import re
import subprocess

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

# Built by concatenation so this file does not match its own guard.
LEGACY_NAMES = ("FORWARD" + "_FNS", "PALLAS" + "_PATHS")

# Files that may legitimately mention the retired names: PR history,
# the issue text that ordered the removal, the lint ban list, and this
# guard itself.
LEGACY_ALLOWED = {
    "CHANGES.md",
    "ISSUE.md",
    "ruff.toml",
    "tests/test_repo_hygiene.py",
}


def _git(*args):
    return subprocess.run(
        ["git", *args], cwd=REPO, capture_output=True, text=True)


@pytest.fixture(scope="module")
def tracked_files():
    res = _git("ls-files")
    if res.returncode != 0:
        pytest.skip(f"not a git checkout: {res.stderr.strip()}")
    return res.stdout.splitlines()


def test_no_tracked_bytecode(tracked_files):
    bad = [f for f in tracked_files
           if f.endswith(".pyc") or "__pycache__" in f.split("/")]
    assert not bad, (
        f"tracked bytecode artifacts (git rm -r --cached them): {bad}")


def test_gitignore_covers_bytecode_and_bench_scratch():
    patterns = (REPO / ".gitignore").read_text().splitlines()
    for required in ("__pycache__/", "*.pyc", "bench_out/"):
        assert required in patterns, (
            f".gitignore lost the {required!r} rule — bytecode/scratch "
            "would start showing up in git status (and risk being added)")


def test_git_would_ignore_a_stray_pyc():
    """The patterns actually work, not just exist: check-ignore must
    match representative paths (never touches the filesystem)."""
    res = _git("check-ignore", "-q", "src/repro/__pycache__/x.pyc")
    if res.returncode == 128:
        pytest.skip(f"git check-ignore unavailable: {res.stderr.strip()}")
    assert res.returncode == 0


def test_no_legacy_forward_path_surfaces(tracked_files):
    """Grep every tracked text file for the retired names.  New code
    must go through ``paths.available()`` / ``paths.get()``."""
    pattern = re.compile("|".join(map(re.escape, LEGACY_NAMES)))
    offenders = []
    for rel in tracked_files:
        if rel in LEGACY_ALLOWED:
            continue
        path = REPO / rel
        try:
            text = path.read_text(encoding="utf-8")
        except (UnicodeDecodeError, FileNotFoundError):
            continue
        for i, line in enumerate(text.splitlines(), 1):
            if pattern.search(line):
                offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, (
        "retired forward-path surface names resurfaced (use the "
        "repro.core.paths registry instead):\n" + "\n".join(offenders))
