"""Repo hygiene: no bytecode artifacts, no resurrected legacy API names.

A tracked ``__pycache__`` directory once shadowed a real package at
import time (``src/repro/serving/__pycache__`` survived a refactor and
Python happily imported the stale ``.pyc``s) — the failure mode is
silent and maddening, so tier-1 fails fast on any tracked bytecode and
on a ``.gitignore`` that stopped covering it.  CI runs the same check
shell-side in the lint job; this test makes it bite locally too.

The legacy-name guard moved into the lint framework
(``repro.analysis.rules.retired_names``, rule id ``retired-names``,
allowlist in ``analysis.toml``); the test here is the thin tier-1
assertion that the rule reports zero findings, with ruff's TID251 bans
as the second line of defense for imports.
"""

import pathlib
import subprocess

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def _git(*args):
    return subprocess.run(
        ["git", *args], cwd=REPO, capture_output=True, text=True)


@pytest.fixture(scope="module")
def tracked_files():
    res = _git("ls-files")
    if res.returncode != 0:
        pytest.skip(f"not a git checkout: {res.stderr.strip()}")
    return res.stdout.splitlines()


def test_no_tracked_bytecode(tracked_files):
    bad = [f for f in tracked_files
           if f.endswith(".pyc") or "__pycache__" in f.split("/")]
    assert not bad, (
        f"tracked bytecode artifacts (git rm -r --cached them): {bad}")


def test_gitignore_covers_bytecode_and_bench_scratch():
    patterns = (REPO / ".gitignore").read_text().splitlines()
    for required in ("__pycache__/", "*.pyc", "bench_out/"):
        assert required in patterns, (
            f".gitignore lost the {required!r} rule — bytecode/scratch "
            "would start showing up in git status (and risk being added)")


def test_git_would_ignore_a_stray_pyc():
    """The patterns actually work, not just exist: check-ignore must
    match representative paths (never touches the filesystem)."""
    res = _git("check-ignore", "-q", "src/repro/__pycache__/x.pyc")
    if res.returncode == 128:
        pytest.skip(f"git check-ignore unavailable: {res.stderr.strip()}")
    assert res.returncode == 0


def test_no_legacy_forward_path_surfaces():
    """The ``retired-names`` lint rule reports zero findings: new code
    must go through ``paths.available()`` / ``paths.get()``."""
    from repro.analysis.config import AnalysisConfig
    from repro.analysis.lint import run_lint
    from repro.analysis.rules.retired_names import RetiredNamesRule
    findings = run_lint(REPO, [RetiredNamesRule()], AnalysisConfig.load(REPO))
    assert findings == [], "\n".join(f.render() for f in findings)
