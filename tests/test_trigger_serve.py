"""launch.trigger_serve: the double-buffered serve_stream loop edge cases
and the thin-CLI-over-engine entry point."""

import jax
import numpy as np

from repro.launch import trigger_serve
from repro.launch.trigger_serve import make_stream, serve_stream
from repro.serving import ServingMetrics


def _identity_fwd():
    """A jitted async-dispatch stand-in for a forward path."""
    return jax.jit(lambda x: x * 2.0)


def _stream(n_batches, batch=4):
    return [np.full((batch, 3), float(i), np.float32)
            for i in range(n_batches)]


def test_serve_stream_warmup_longer_than_stream_is_empty_stats():
    """warmup >= stream length: every batch is warmup — empty stats, no
    crash, and the degenerate wall stays 0 (callers print 'too short')."""
    for n in (0, 1, 2):
        lat, events, wall = serve_stream(_identity_fwd(), _stream(n),
                                         warmup=2)
        assert lat == []
        assert events == 0
        if n == 0:
            assert wall == 0.0


def test_serve_stream_excludes_warmup_from_accounting():
    fwd = _identity_fwd()
    lat, events, wall = serve_stream(fwd, _stream(7, batch=5), warmup=2)
    assert len(lat) == 5                  # 7 batches - 2 warmup
    assert events == 5 * 5                # KGPS accounting skips warmup rows
    assert wall > 0
    assert all(t > 0 for t in lat)


def test_serve_stream_single_batch_stream():
    """The prefetch loop must handle a 1-batch stream: the primed transfer
    is the only batch, and with warmup=0 it is measured — including a
    positive wall time so KGPS stays finite."""
    fwd = _identity_fwd()
    lat, events, wall = serve_stream(fwd, _stream(1, batch=3), warmup=0)
    assert len(lat) == 1
    assert events == 3
    assert wall > 0.0


def test_serve_stream_records_into_metrics():
    m = ServingMetrics()
    serve_stream(_identity_fwd(), _stream(6, batch=4), warmup=2,
                 metrics=m, bucket=8)
    snap = m.snapshot()
    assert snap["batches"] == 4
    assert snap["events"] == 16
    assert snap["buckets"] == [8]


def test_serve_stream_computes_through_the_pipeline():
    """Double buffering must not drop or reorder batches."""
    fwd = _identity_fwd()
    stream = _stream(4, batch=2)
    outs = []
    orig = jax.device_put

    def capture(x):
        d = orig(x)
        outs.append(np.asarray(x)[0, 0])
        return d

    jax.device_put, saved = capture, jax.device_put
    try:
        serve_stream(fwd, stream, warmup=0)
    finally:
        jax.device_put = saved
    assert outs == [0.0, 1.0, 2.0, 3.0]


def test_make_stream_shapes():
    rng = np.random.RandomState(0)
    stream = make_stream(rng, 3, batch=6, n_objects=8, n_features=16)
    assert len(stream) == 3
    assert all(b.shape == (6, 8, 16) and b.dtype == np.float32
               for b in stream)


def test_cli_main_reports_stats_through_engine(capsys):
    trigger_serve.main(["--forward", "sr", "--n-objects", "8",
                        "--batch", "8", "--batches", "5", "--warmup", "1"])
    out = capsys.readouterr().out
    assert "sustained" in out and "KGPS" in out
    assert "p50" in out and "p99" in out
    assert "roofline" in out and "level=none" in out


def test_cli_main_short_stream_prints_hint(capsys):
    trigger_serve.main(["--forward", "sr", "--n-objects", "8",
                        "--batch", "4", "--batches", "2"])
    out = capsys.readouterr().out
    assert "too short" in out


def test_cli_main_fused_full_interpret(capsys):
    """The acceptance path, shrunk: fused_full through the engine on CPU."""
    trigger_serve.main(["--forward", "fused_full", "--interpret",
                        "--n-objects", "8", "--batch", "4", "--batches", "4",
                        "--warmup", "1"])
    out = capsys.readouterr().out
    assert "KGPS" in out and "level=full" in out


def test_cli_list_paths_prints_fallback_chains_and_policy(capsys):
    """--list-paths is the operator's view of the degradation ladder:
    the registry table must carry each path's fallback chain next to
    its resolved bucket policy."""
    trigger_serve.main(["--list-paths", "--n-objects", "8", "--batch", "16"])
    out = capsys.readouterr().out
    assert "fallback chain" in out
    assert "fused_full>sr_split" in out      # int8 path's two-rung chain
    assert "bucket policy" in out


def test_cli_health_flag_reports_state(capsys):
    trigger_serve.main(["--forward", "sr", "--n-objects", "8",
                        "--batch", "8", "--batches", "5", "--warmup", "1",
                        "--health"])
    out = capsys.readouterr().out
    assert "[health] state=healthy" in out
    assert "chain=sr" in out
    assert "path=sr" in out                  # serving line + bucket detail


def test_cli_reports_serving_path_and_chain(capsys):
    trigger_serve.main(["--forward", "fused_full", "--interpret",
                        "--n-objects", "8", "--batch", "4", "--batches", "4",
                        "--warmup", "1"])
    out = capsys.readouterr().out
    assert "path=fused_full" in out and "chain fused_full>sr_split" in out
