"""Sharding rules: logical->physical mapping, divisibility fallback,
state/cache shardings, and a real multi-device pjit run on a fake mesh.

Uses a subprocess-free trick: tests in this file create a 4-device CPU
mesh via jax.sharding over the single device? No — JAX needs real devices.
Instead these tests run structure-level assertions (specs) which don't
need devices, plus one guarded multi-device test that only runs when the
test session was started with XLA_FLAGS device_count>1 (see
tests/test_multidevice.py for the subprocess-based version).
"""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as shd


class FakeMesh:
    """Duck-typed mesh: axis_names + shape dict (enough for spec logic)."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


def test_filter_axes_drops_missing():
    m = FakeMesh({"data": 16, "model": 16})
    assert shd._filter_axes(("pod", "data"), m) == "data"
    assert shd._filter_axes(("pod",), m) is None
    assert shd._filter_axes(("data", "model"), m) == ("data", "model")


def test_divisible_entry_prefix_rule():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    # full product divides
    assert shd._divisible_entry(512, ("pod", "data", "model"), m) == \
        ("pod", "data", "model")
    # only pod*data divides 32
    assert shd._divisible_entry(32, ("pod", "data", "model"), m) == \
        ("pod", "data")
    # nothing divides 7
    assert shd._divisible_entry(7, ("pod", "data", "model"), m) is None
    # 8 kv heads on 16-way model -> dropped
    assert shd._divisible_entry(8, ("model",), m) is None


def test_logical_to_spec_known_axes():
    m = FakeMesh({"data": 16, "model": 16})
    spec = shd.logical_to_spec(("batch", None, "heads"), m,
                               shd.DEFAULT_RULES)
    assert spec == P("data", None, "model")
    with pytest.raises(KeyError):
        shd.logical_to_spec(("nope",), m, shd.DEFAULT_RULES)


def _abstract(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_param_rules_lm_paths():
    """Param path regexes give TP+FSDP for attention/FFN, EP for experts."""
    m = FakeMesh({"data": 16, "model": 16})
    spec = shd._spec_for_path("layers/attn/wq/w", 3, m, shd.DEFAULT_RULES,
                              (4, 2048, 2048))
    assert tuple(spec) == (None, "data", "model")
    spec = shd._spec_for_path("layers/moe/experts/w_gate", 4, m,
                              shd.DEFAULT_RULES, (4, 128, 2048, 4864))
    assert tuple(spec) == (None, "data", None, "model")
    spec = shd._spec_for_path("embed/w", 2, m, shd.DEFAULT_RULES,
                              (32000, 4096))
    assert tuple(spec) == ("model", "data")
    # non-dividing fan-in falls back (1433 % 16 != 0)
    spec = shd._spec_for_path("gnn_layers/0/w", 2, m, shd.DEFAULT_RULES,
                              (1433, 16))
    assert tuple(spec) == (None, None)


def _run_with_fake_devices(code: str) -> str:
    """NamedSharding needs a real Mesh; run spec checks in a subprocess
    with 256 fake devices so 16x16 meshes exist."""
    import subprocess
    import sys
    import textwrap
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=256'\n"
            + textwrap.dedent(code))
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=600, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_state_shardings_structure():
    """Adafactor factored accs inherit the param spec minus reduced dim."""
    out = _run_with_fake_devices("""
        import jax, jax.numpy as jnp
        from repro.parallel import sharding as shd
        from repro.training import make_optimizer
        from repro.training.schedule import constant

        mesh = jax.make_mesh((16, 16), ("data", "model"))
        params = {"layers": {"attn": {"wq": {
            "w": jax.ShapeDtypeStruct((4, 2048, 2048), jnp.float32)}}}}
        opt = make_optimizer("adafactor", constant(1e-3))
        opt_state = jax.eval_shape(opt.init, params)
        state = {"params": params, "opt": opt_state,
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
        sh = shd.train_state_shardings(state, mesh)
        print("P", tuple(sh["params"]["layers"]["attn"]["wq"]["w"].spec))
        acc = sh["opt"]["acc"]["layers"]["attn"]["wq"]["w"]
        print("R", tuple(acc["r"].spec))
        print("C", tuple(acc["c"].spec))
    """)
    assert "P (None, 'data', 'model')" in out
    assert "R (None, 'data')" in out          # minus last dim
    assert "C (None, 'model')" in out         # minus second-to-last


def test_kv_cache_shardings_fallback():
    """kv=8 heads on a 16-way model axis -> seq-sharded cache."""
    out = _run_with_fake_devices("""
        import jax, jax.numpy as jnp
        from repro.parallel import sharding as shd

        mesh = jax.make_mesh((16, 16), ("data", "model"))
        def sds(shape, dt=jnp.float32):
            return jax.ShapeDtypeStruct(shape, dt)
        cache = {
            "k": sds((32, 128, 32768, 8, 128)),
            "v": sds((32, 128, 32768, 8, 128)),
            "slot_pos": sds((128, 32768), jnp.int32),
            "pos": sds((128,), jnp.int32),
        }
        sh = shd.kv_cache_shardings(cache, mesh)
        print("A", tuple(sh["k"].spec))
        cache["k"] = sds((32, 128, 32768, 16, 128))
        cache["v"] = cache["k"]
        sh = shd.kv_cache_shardings(cache, mesh)
        print("B", tuple(sh["k"].spec))
    """)
    assert "A (None, 'data', 'model', None, None)" in out  # seq-sharded
    assert "B (None, 'data', None, 'model', None)" in out  # head-sharded


def test_constrain_is_noop_without_context():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", None)
    assert y is x
