"""Whole-network fused kernel: equivalence, tiling/padding, precision.

The acceptance bar for ``forward_fused_full`` is max abs err < 1e-4 vs
``forward_sr`` in fp32 interpret mode.  Tests use LeCun-init weights and
the standardized jet generator so logits sit at trained-model scale
(O(1)-O(10)); He init on an UNTRAINED net blows activations up ~N_o-fold
per message hop, which turns fp32 reordering noise into O(1e-4) absolute
differences that say nothing about the kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codesign, interaction_net as inet
from repro.data.jets import make_jets
from repro.kernels.fused_jedinet import autotune
from repro.kernels.fused_jedinet import ops as fj_ops


def _setup(n_o, fr_hidden, fo_hidden, batch, **cfg_kw):
    cfg = inet.JediNetConfig(n_objects=n_o, n_features=16,
                             fr_hidden=fr_hidden, fo_hidden=fo_hidden,
                             **cfg_kw)
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    x, _ = make_jets(np.random.RandomState(1), batch, n_o)
    return cfg, params, jnp.asarray(x)


# --- equivalence vs forward_sr (the acceptance criterion) -------------------

@pytest.mark.parametrize("n_o,fr,fo,batch", [
    (30, (20, 20, 20), (20, 20, 20), 4),     # paper 30p
    (50, (8, 8), (32, 32, 32), 4),           # paper U4-like 50p
])
def test_fused_full_equals_sr_fp32(n_o, fr, fo, batch):
    cfg, params, x = _setup(n_o, fr, fo, batch)
    sr = inet.forward_sr(params, cfg, x)
    full = inet.forward_fused_full(params, cfg, x, interpret=True)
    assert full.dtype == jnp.float32
    err = float(jnp.max(jnp.abs(sr - full)))
    assert err < 1e-4, f"max abs err {err:.2e} >= 1e-4"


@pytest.mark.parametrize("batch", [1, 3, 7, 13, 17])
def test_fused_full_odd_prime_batches(batch):
    """Non-divisible batches are padded to the tile, never degraded."""
    cfg, params, x = _setup(30, (20, 20, 20), (20, 20, 20), batch)
    sr = inet.forward_sr(params, cfg, x)
    full = inet.forward_fused_full(params, cfg, x, interpret=True)
    assert full.shape == (batch, cfg.n_targets)
    np.testing.assert_allclose(np.asarray(sr), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_fused_full_explicit_block_b_padding():
    """block_b > batch and block_b ∤ batch both work via padding."""
    cfg, params, x = _setup(13, (16, 12), (10,), 7)
    base = fj_ops.fused_forward_full(params, cfg, x, interpret=True,
                                     block_b=1)
    for bb in (2, 4, 8, 16):
        out = fj_ops.fused_forward_full(params, cfg, x, interpret=True,
                                        block_b=bb)
        np.testing.assert_allclose(np.asarray(base), np.asarray(out),
                                   rtol=1e-5, atol=1e-6)


def test_fused_full_bf16_vs_fp32():
    """bf16 compute with fp32 accumulation: ~1e-2 of fp32, not garbage."""
    cfg, params, x = _setup(30, (20, 20, 20), (20, 20, 20), 6)
    fp32 = inet.forward_fused_full(params, cfg, x, interpret=True)
    bcfg = cfg.with_(compute_dtype="bfloat16")
    bf16 = inet.forward_fused_full(params, bcfg, x, interpret=True)
    assert bf16.dtype == jnp.float32          # fp32 accumulation out
    err = float(jnp.max(jnp.abs(fp32 - bf16)))
    scale = float(jnp.max(jnp.abs(fp32)))
    assert err < 5e-2 * max(scale, 1.0), (err, scale)
    # and bf16 really changed the numerics (the cast path is live)
    assert err > 0.0


def test_fused_edge_block_bf16_compute_dtype():
    """cfg.compute_dtype threads into the edge kernel too."""
    cfg, params, x = _setup(30, (20, 20), (20,), 4)
    fp32 = fj_ops.fused_edge_block(params["fr"], cfg, x, interpret=True)
    bcfg = cfg.with_(compute_dtype="bfloat16")
    bf16 = fj_ops.fused_edge_block(params["fr"], bcfg, x, interpret=True)
    err = float(jnp.max(jnp.abs(fp32 - bf16)))
    scale = float(jnp.max(jnp.abs(fp32)))
    assert 0.0 < err < 5e-2 * max(scale, 1.0), (err, scale)


def test_path_registered_in_registry():
    from repro.core import paths
    assert "fused_full" in paths.available()
    assert paths.get("fused_full").forward is inet.forward_fused_full


# --- autotuner --------------------------------------------------------------

def test_pick_block_b_prime_batch_not_degraded():
    """The old divisor rule forced block_b=1 on B=1009; the autotuner keeps
    a near-VMEM-optimal balanced tile and relies on padding."""
    per_sample = 30 * 30 * 20 * 4                       # ~72 KB
    bb = autotune.pick_block_b(1009, per_sample)
    assert bb > 1
    assert bb * per_sample <= autotune.VMEM_BUDGET_BYTES
    assert autotune.padded_batch(1009, bb) % bb == 0
    assert autotune.padded_batch(1009, bb) - 1009 < bb  # sub-tile waste


def test_pick_block_b_respects_budget_and_batch():
    assert autotune.pick_block_b(4, 1024) == 4          # capped by batch
    huge = autotune.VMEM_BUDGET_BYTES                   # 1 sample fills VMEM
    assert autotune.pick_block_b(1024, huge) == 1
    # whole batch fits -> one grid step, zero padding (no forced alignment)
    assert autotune.pick_block_b(100, 1024) == 100
    assert autotune.pick_block_b(1024, 1) == 1024


def test_pick_block_b_balances_steps():
    """Budget tile 96 on B=256: 3 steps either way, so the tile balances
    down to 88 (8 padded rows) instead of 96 (32 padded rows)."""
    per_sample = autotune.VMEM_BUDGET_BYTES // 96
    bb = autotune.pick_block_b(256, per_sample)
    assert bb * per_sample <= autotune.VMEM_BUDGET_BYTES
    steps = autotune.padded_batch(256, bb) // bb
    assert steps == 3
    assert autotune.padded_batch(256, bb) - 256 <= 8
    assert bb % 8 == 0                                  # aligned fits here


def test_pad_batch_shapes_and_zeros():
    x = jnp.ones((7, 5, 3))
    xp = autotune.pad_batch(x, 4)
    assert xp.shape == (8, 5, 3)
    np.testing.assert_array_equal(np.asarray(xp[7]), 0.0)
    assert autotune.pad_batch(x, 7) is x                # exact multiple: no-op


def test_working_set_full_exceeds_edge():
    fr, fo, phi = [20, 20, 20, 8], [20, 20, 20, 24], [20, 20, 20, 5]
    edge = autotune.edge_block_bytes_per_sample(30, 16, fr)
    full = autotune.full_forward_bytes_per_sample(30, 16, fr, fo, phi)
    assert full > edge > 0


# --- codesign model: fusion levels ------------------------------------------

@pytest.mark.parametrize("n_o", [30, 50])
def test_tpu_model_full_strictly_lower_hbm(n_o):
    cfg = inet.JediNetConfig(n_objects=n_o, n_features=16)
    pt = codesign.TPUDesignPoint(cfg=cfg, batch=1024)
    none = codesign.TPUModel.evaluate(pt, "none")
    edge = codesign.TPUModel.evaluate(pt, "edge")
    full = codesign.TPUModel.evaluate(pt, "full")
    assert full["hbm_bytes"] < edge["hbm_bytes"] < none["hbm_bytes"]
    assert full["fused_level"] == "full"
    # the legacy bool levels are gone — False used to coerce silently
    for legacy in (True, False, "both"):
        with pytest.raises(ValueError):
            codesign.TPUModel.evaluate(pt, legacy)
    # quantized weight precision cuts HBM below the same level's fp bill
    int8 = codesign.TPUModel.evaluate(pt, "full", weight_bytes=1)
    assert int8["hbm_bytes"] < full["hbm_bytes"]
    assert int8["weight_bytes"] == 1 and full["weight_bytes"] == 2


def test_explore_uses_full_level_by_default():
    base = inet.JediNetConfig()
    out = codesign.explore(base, max_candidates=40,
                           fr_nl=(1,), fr_size=(8,), fo_first=(16,),
                           n_fr_opts=(29,), r_fo_opts=(1,))
    assert out["n_survivors"] > 0
    for c in out["survivors"]:
        assert c.tpu["fused_level"] == "full"
