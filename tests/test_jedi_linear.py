"""JEDI-linear path: the O(N_o) pooling identity, the fused kernel,
the int8 in-kernel dequant variant, and the linear live-set VMEM model.

The registry-parametrized suites in test_paths.py already check every
jedi path against its registered edge-sum oracle at serving shapes;
this file pins down the properties that make the path worth having —
the identity holds as N_o grows (incl. the 128-track regime the grid
kernel's VMEM model rejects outright), prime batches pad instead of
degrading the tile, and the bytes model really is linear in N_o.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.interaction_net import JediNetConfig, init
from repro.core.int8_path import dequantize_params, quantize_params_int8
from repro.core.jedi_linear_path import (
    JEDI_LINEAR_FUSED_TOLERANCE,
    JEDI_LINEAR_TOLERANCE,
)
from repro.kernels.fused_jedinet import autotune as grid_autotune
from repro.kernels.jedi_linear import autotune, ops, ref


def _setup(n_objects, batch, seed=0):
    cfg = JediNetConfig(n_objects=n_objects, n_features=16)
    params = init(jax.random.PRNGKey(seed), cfg, scale="lecun")
    rng = np.random.RandomState(seed + 1)
    x = jnp.asarray(rng.normal(0, 1, (batch, n_objects, 16)).astype(np.float32))
    return cfg, params, x


def _widths(params):
    return (autotune.mlp_widths(params["fr"]),
            autotune.mlp_widths(params["fo"]),
            autotune.mlp_widths(params["phi"]))


# -- the O(N_o) identity --------------------------------------------------


@pytest.mark.parametrize("n_objects", [8, 30, 50, 128])
def test_pooled_identity_matches_edge_sum_oracle(n_objects):
    """The telescoped (pooled) aggregation equals the explicit masked
    edge-grid sum at every graph size, including 128 tracks where the
    recombination multiplies u_r by 127."""
    cfg, params, x = _setup(n_objects, 4)
    pooled = ref.forward_jedi_linear(params, cfg, x)
    oracle = ref.forward_jedi_linear_edge_sum(params, cfg, x)
    assert pooled.shape == (4, cfg.n_targets)
    err = float(jnp.max(jnp.abs(pooled - oracle)))
    assert err < JEDI_LINEAR_TOLERANCE, (n_objects, err)


def test_identity_is_not_trivially_zero():
    """Guard against a degenerate pass: logits vary across jets and the
    aggregation actually contributes (zeroing u_s changes the output)."""
    cfg, params, x = _setup(30, 4)
    out = ref.forward_jedi_linear(params, cfg, x)
    assert float(jnp.std(out)) > 0
    u_r, u_s, b1 = ref.first_layer_split(params, cfg, x)
    h_no_send = (cfg.n_objects - 1) * (u_r + b1)
    different = ref._tail(params, cfg, x, h_no_send)
    assert float(jnp.max(jnp.abs(out - different))) > 1e-3


# -- the fused kernel -----------------------------------------------------


@pytest.mark.parametrize("n_objects,batch", [(8, 8), (30, 5), (128, 3)])
def test_fused_kernel_matches_oracle(n_objects, batch):
    cfg, params, x = _setup(n_objects, batch)
    got = ops.jedi_linear_forward_full(params, cfg, x, interpret=True)
    oracle = ref.forward_jedi_linear_edge_sum(params, cfg, x)
    err = float(jnp.max(jnp.abs(got - oracle)))
    assert err < JEDI_LINEAR_FUSED_TOLERANCE, (n_objects, batch, err)


def test_pinned_block_b_pads_prime_batch():
    """A pinned tile that does not divide the batch pads up and slices
    back — prime batches keep the caller's tile choice."""
    cfg, params, x = _setup(30, 7)
    got = ops.jedi_linear_forward_full(params, cfg, x, interpret=True,
                                       block_b=4)
    want = ops.jedi_linear_forward_full(params, cfg, x, interpret=True,
                                        block_b=7)
    assert got.shape == (7, cfg.n_targets)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-5


def test_int8_in_kernel_dequant_matches_boundary_dequant():
    """int8 weights riding the same kernel (scales folded into the fp32
    accumulator) agree with dequantize-at-the-boundary + fp32 kernel to
    kernel fidelity — the quantization error itself cancels out."""
    cfg, params, x = _setup(30, 5)
    qp = quantize_params_int8(params)
    got = ops.jedi_linear_forward_full(qp, cfg, x, interpret=True)
    want = ops.jedi_linear_forward_full(dequantize_params(qp), cfg, x,
                                        interpret=True)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < JEDI_LINEAR_FUSED_TOLERANCE, err


# -- the linear live-set model --------------------------------------------


def test_bytes_model_is_linear_in_graph_size():
    cfg, params, _ = _setup(16, 1)
    fr, fo, phi = _widths(params)

    def per(n_o):
        return autotune.linear_forward_bytes_per_sample(n_o, 16, fr, fo, phi)

    # doubling N_o at most doubles the live set (+ the O(1) phi term)
    assert per(128) <= 2 * per(64)
    assert per(64) <= 2 * per(32)
    # and strictly grows
    assert per(32) < per(64) < per(128)


def test_linear_model_fits_where_grid_model_rejects():
    """The headline: at 128 tracks with the widened (256-wide) MLPs the
    untiled grid working set blows the VMEM budget — the slab alone is
    N_o^2 * 256 * 4 B = 16.8 MB — while the linear live set stays under
    a MB: graph size is no longer a VMEM constraint for this path."""
    fr, fo, phi = [256, 256, 256, 8], [256, 256, 256, 24], [256, 256, 256, 5]
    grid = grid_autotune.full_forward_bytes_per_sample(128, 16, fr, fo, phi)
    lin = autotune.linear_forward_bytes_per_sample(128, 16, fr, fo, phi)
    assert not autotune.fits_vmem(grid)
    assert autotune.fits_vmem(lin)
    assert lin * 10 < grid
    # the paper-width 30p config keeps a 10x+ gap too, both fitting
    nfr, nfo, nphi = _widths(_setup(30, 1)[1])
    assert autotune.linear_forward_bytes_per_sample(
        128, 16, nfr, nfo, nphi) * 10 < grid_autotune.\
        full_forward_bytes_per_sample(128, 16, nfr, nfo, nphi)


def test_linear_model_earns_bigger_batch_tiles():
    """No sender slab -> smaller per-sample set than even the smallest
    sender tile of the grid kernel -> a strictly deeper batch tile under
    the same budget."""
    fr, fo, phi = _widths(_setup(30, 1)[1])
    lin = autotune.linear_forward_bytes_per_sample(30, 16, fr, fo, phi)
    tiled = grid_autotune.full_forward_tiled_bytes_per_sample(
        30, 16, fr, fo, phi, block_s=grid_autotune.sender_tile_candidates(30)[0])
    assert lin < tiled
    bb_lin = autotune.pick_block_b_linear(4096, 30, 16, fr, fo, phi)
    bb_grid, _ = grid_autotune.pick_block_b_s(4096, 30, 16, fr, fo, phi)
    assert bb_lin >= bb_grid
    assert bb_lin * lin <= autotune.VMEM_BUDGET_BYTES


def test_kernel_rejects_non_divisible_batch():
    """The raw kernel call is strict — padding is the wrapper's job, and
    the error names the contract."""
    cfg, params, x = _setup(8, 5)
    cdt = jnp.float32
    from repro.kernels.fused_jedinet import full_kernel as FK
    from repro.kernels.fused_jedinet import kernel as K
    from repro.kernels.jedi_linear import linear_kernel as LK
    frs = K.split_first_layer(params["fr"], cfg.n_features, dtype=cdt)
    with pytest.raises(ValueError, match="pad_batch"):
        LK.jedi_linear_kernel_call(
            x, [frs[0], frs[1], frs[2], *frs[3]],
            FK.flatten_mlp(params["fo"], cdt),
            FK.flatten_mlp(params["phi"], cdt),
            activation=cfg.activation, n_targets=cfg.n_targets,
            block_b=4, interpret=True)
