"""EquiformerV2: rotation invariance of the readout + chunked-scan
consistency (the ogb_products execution path)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.gnn import equiformer_v2 as eq
from repro.models.gnn import so3


CFG = GNNConfig(name="eq-test", kind="equiformer_v2", n_layers=2,
                d_hidden=8, n_classes=2, l_max=3, m_max=2, n_heads=2,
                activation="silu")


def _graph(rng, n=16, e=48):
    return {
        "x": jnp.asarray(rng.normal(0, 1, (n, 5)).astype(np.float32)),
        "pos": jnp.asarray(rng.normal(0, 1, (n, 3)).astype(np.float32)),
        "senders": jnp.asarray(rng.randint(0, n, e).astype(np.int32)),
        "receivers": jnp.asarray(rng.randint(0, n, e).astype(np.int32)),
    }


def test_readout_is_rotation_invariant(rng):
    """Energy-style readout must not change under global rotation of pos."""
    g = _graph(rng)
    params = eq.init(jax.random.PRNGKey(0), CFG, 5, 2)
    out = eq.apply(params, CFG, g)

    axis_angle = jnp.asarray(np.array([0.3, -1.1, 0.7], np.float32))
    rot = so3.rotation_matrices(axis_angle)
    g_rot = dict(g, pos=g["pos"] @ rot.T)
    out_rot = eq.apply(params, CFG, g_rot)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_rot),
                               rtol=5e-3, atol=5e-3)


def test_translation_invariance(rng):
    g = _graph(rng)
    params = eq.init(jax.random.PRNGKey(0), CFG, 5, 2)
    out = eq.apply(params, CFG, g)
    g_shift = dict(g, pos=g["pos"] + jnp.asarray([10.0, -3.0, 2.0]))
    out_shift = eq.apply(params, CFG, g_shift)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_shift),
                               rtol=5e-4, atol=5e-4)


def test_edge_chunked_scan_matches_unchunked(rng, monkeypatch):
    """The lax.scan edge-chunk path (ogb_products) == direct path."""
    g = _graph(rng, n=12, e=64)
    params = eq.init(jax.random.PRNGKey(0), CFG, 5, 2)
    out_direct = eq.apply(params, CFG, g)
    monkeypatch.setattr(eq, "_EDGE_CHUNK", 16)     # force chunking (64/16=4)
    out_chunked = eq.apply(params, CFG, g)
    np.testing.assert_allclose(np.asarray(out_direct),
                               np.asarray(out_chunked),
                               rtol=2e-4, atol=2e-4)


def test_so2_conv_respects_m_truncation(rng):
    """Components with |m| > m_max must be zeroed by the eSCN conv."""
    cfg = dataclasses.replace(CFG, m_max=1)
    params = eq.init(jax.random.PRNGKey(1), cfg, 5, 2)
    lp = jax.tree_util.tree_map(lambda x: x, params["gnn_layers"][0])
    e_cnt, k, c = 6, (cfg.l_max + 1) ** 2, cfg.d_hidden
    x_rot = jnp.asarray(rng.normal(0, 1, (e_cnt, k, c)).astype(np.float32))
    gates = jnp.ones((e_cnt, cfg.m_max + 1, c), jnp.float32)
    y = eq._so2_conv(lp, cfg, x_rot, gates)
    for l in range(cfg.l_max + 1):
        for m in range(-l, l + 1):
            comp = np.asarray(y[:, so3.flat_index(l, m), :])
            if abs(m) > cfg.m_max:
                assert np.all(comp == 0), (l, m)
