"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device; only launch/dryrun.py forces 512 placeholder devices."""

import jax
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection suite for the resilient serving layer "
        "(runs in tier-1 AND standalone in CI's chaos job via -m chaos)")
    config.addinivalue_line(
        "markers",
        "sentinel: silent-corruption sentinel suite (canaries, shadow "
        "re-execution, canary-gated quarantine); runs in tier-1 AND in "
        "CI's chaos job via -m 'chaos or sentinel'")


@pytest.fixture
def rng():
    return np.random.RandomState(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def random_graph(rng, n=20, e=60, d=8, with_pos=False, n_classes=3):
    import jax.numpy as jnp
    g = {
        "x": jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32)),
        "senders": jnp.asarray(rng.randint(0, n, e).astype(np.int32)),
        "receivers": jnp.asarray(rng.randint(0, n, e).astype(np.int32)),
        "y": jnp.asarray(rng.randint(0, n_classes, n).astype(np.int32)),
    }
    if with_pos:
        g["pos"] = jnp.asarray(rng.normal(0, 1, (n, 3)).astype(np.float32))
    return g
