"""Silent-corruption sentinel suite: canaries, shadows, quarantine.

The coverage target is the failure mode the chaos suite cannot see:
finite, shaped, WRONG logits (a drifted int8 scale, a corrupted weight
tensor, a stale compile-cache entry).  These tests drive the sentinel's
three mechanisms deterministically on CPU — golden canaries through the
live pinned-bucket path, duty-cycled terminal-rung shadow re-execution,
and canary-gated quarantine/requalification — plus the thread-safety
satellite on :class:`ServingMetrics`.

All tests carry the ``sentinel`` marker: they run in tier-1 and
standalone in CI's chaos job (``pytest -m "chaos or sentinel"``).
"""

import threading

import jax
import numpy as np
import pytest

from repro.core import paths
from repro.core.interaction_net import JediNetConfig, forward_sr, init
from repro.serving import (
    FaultInjector,
    ResilientEngine,
    SentinelConfig,
    ServingMetrics,
)

pytestmark = pytest.mark.sentinel

#: (path, seam, factor) triples covering every silent seam, each on a
#: path where the corruption actually bites (scale_drift needs int8).
SILENT_CASES = [
    ("int8_fused_full", "scale_drift", 8.0),
    ("fused_full", "weight_corrupt", 8.0),
    ("fused_full", "stale_cache", 1.0),
]


@pytest.fixture(scope="module")
def jedi8():
    cfg = JediNetConfig(n_objects=8, n_features=16)
    params = init(jax.random.PRNGKey(0), cfg, scale="lecun")
    rng = np.random.RandomState(0)
    x = rng.normal(0, 1, (5, 8, 16)).astype(np.float32)
    ref = np.asarray(forward_sr(params, cfg, x))
    return cfg, params, x, ref


def _engine(jedi, injector=None, sentinel=None, **kw):
    cfg, params, _, _ = jedi
    kw.setdefault("forward", "fused_full")
    kw.setdefault("interpret", True)
    kw.setdefault("max_batch", 16)
    if sentinel is None:
        sentinel = SentinelConfig(canary_every=4, promote_after=2,
                                  shadow_rate=0.25, shadow_sync=True)
    return ResilientEngine(params, cfg, injector=injector,
                           sentinel=sentinel, **kw)


# -- registry helper ------------------------------------------------------


def test_terminal_rung_resolves_chain_bottom():
    for name in paths.available():
        term = paths.terminal_rung(name)
        assert term == paths.fallback_chain(name)[-1]
        assert not paths.get(term).pallas


# -- canary detection -----------------------------------------------------


@pytest.mark.parametrize("path,seam,factor", SILENT_CASES)
def test_canary_detects_and_quarantines_each_silent_seam(
        jedi8, path, seam, factor):
    """Every silent seam is caught by the FIRST canary (build-time
    corruption lives in the cached callable, and a bucket's first
    observed request always canaries) — one batch of detection
    latency, zero exceptions, and never a ``healthy`` report while
    the corruption serves."""
    cfg, params, x, _ = jedi8
    inj = FaultInjector()
    inj.arm(seam, path=path, factor=factor)          # persistent corruption
    eng = _engine(jedi8, inj, forward=path)
    out = eng.infer(x)                               # must never raise
    assert out.shape == (5, cfg.n_targets) and np.isfinite(out).all()
    h = eng.health()
    assert h["state"] == "quarantined"
    assert h["counters"]["sentinel_trips"] >= 1
    assert h["counters"]["quarantines"] == 1
    b = h["buckets"][eng.bucket_for(5)]
    assert b["quarantined"] and b["quarantined_path"] == path


@pytest.mark.parametrize("path,seam,factor", SILENT_CASES)
def test_quarantine_requalifies_after_clean_canaries(jedi8, path, seam,
                                                     factor):
    """A one-shot corruption (times=1): the trip evicts the poisoned
    cache entry, the rebuild is clean, and ``promote_after``
    consecutive clean canaries re-promote — the self-healing story."""
    cfg, params, x, _ = jedi8
    inj = FaultInjector()
    inj.arm(seam, path=path, times=1, factor=factor)
    eng = _engine(jedi8, inj, forward=path)
    states = []
    for _ in range(12):
        out = eng.infer(x)
        assert np.isfinite(out).all()
        states.append(eng.health()["state"])
    assert states[0] == "quarantined"                # caught on request 1
    assert states[-1] == "healthy"                   # ...and healed
    # no healthy report in between: quarantined until requalification
    first_healthy = states.index("healthy")
    assert all(s == "quarantined" for s in states[:first_healthy])
    c = eng.metrics.counters
    assert c["requalifications"] == 1
    assert c["canary_mismatches"] == 1
    assert eng.active_path(eng.bucket_for(5)) == path


def test_persistent_corruption_never_requalifies(jedi8):
    """times=inf: every post-eviction rebuild re-corrupts, so every
    requalification canary is dirty and the bucket stays quarantined —
    serving the clean fallback rung the whole time."""
    cfg, params, x, ref = jedi8
    inj = FaultInjector()
    inj.arm("weight_corrupt", path="fused_full", factor=8.0)
    eng = _engine(jedi8, inj)
    eng.infer(x)          # request 1 serves corrupted (1-batch detection)
    assert eng.health()["state"] == "quarantined"
    for _ in range(15):
        out = eng.infer(x)
        # the fallback rung (sr_split) serves CORRECT answers throughout
        assert np.abs(out - ref).max() < 1e-3
    h = eng.health()
    assert h["state"] == "quarantined"
    assert h["counters"]["sentinel_trips"] >= 2      # re-tripped on requal
    assert "requalifications" not in h["counters"]


def test_quarantined_bucket_never_probes_live_traffic(jedi8):
    """Re-promotion out of quarantine is canary-gated: the backoff
    probe machinery must NOT route live requests at the quarantined
    rung (it could LOOK healthy to a probe on non-canary input)."""
    cfg, params, x, _ = jedi8
    t = [0.0]
    inj = FaultInjector()
    inj.arm("weight_corrupt", path="fused_full", factor=8.0)
    eng = _engine(jedi8, inj, clock=lambda: t[0], probe_initial_s=0.01)
    for _ in range(8):
        eng.infer(x)
        t[0] += 10.0                                 # way past any backoff
    assert eng.health()["state"] == "quarantined"
    assert "probes" not in eng.metrics.counters


# -- shadow re-execution --------------------------------------------------


def test_shadow_reexecution_feeds_agreement_stats(jedi8):
    """Fault-free serving: the duty-cycled shadow sample re-runs on the
    terminal rung and lands EWMA agreement gauges; nothing trips."""
    cfg, params, x, _ = jedi8
    eng = _engine(jedi8, sentinel=SentinelConfig(
        canary_every=100, shadow_rate=0.5, shadow_sync=True))
    for _ in range(8):
        eng.infer(x)
    m = eng.metrics
    b = eng.bucket_for(5)
    assert m.counter("shadow_requests") >= 3
    assert m.gauge_value(f"shadow_dev_ewma_b{b}") < 1e-2
    assert m.gauge_value(f"shadow_argmax_ewma_b{b}") == 0.0
    assert "shadow_disagreements" not in m.counters
    assert eng.health()["state"] == "healthy"


def test_shadow_trips_quarantine_when_canary_is_blind(jedi8):
    """The shadow path is an independent detector: with the golden
    table emptied (canaries can only error out), live-vs-terminal
    disagreement alone must still quarantine the corrupted rung."""
    cfg, params, x, _ = jedi8
    inj = FaultInjector()
    inj.arm("weight_corrupt", path="fused_full", factor=8.0)
    eng = _engine(jedi8, inj, sentinel=SentinelConfig(
        canary_every=1000, shadow_rate=1.0, shadow_sync=True))
    eng.sentinel._golden.clear()                     # blind the canaries
    for _ in range(4):
        eng.infer(x)
    h = eng.health()
    assert h["state"] == "quarantined"
    assert h["counters"]["shadow_disagreements"] >= 1
    assert h["counters"]["quarantines"] == 1


def test_shadow_worker_thread_applies_trips_on_serve_thread(jedi8):
    """Async mode: the worker only RECORDS trips; the serve thread
    applies them at its next observe (or an explicit drain)."""
    cfg, params, x, _ = jedi8
    inj = FaultInjector()
    inj.arm("weight_corrupt", path="fused_full", factor=8.0)
    eng = _engine(jedi8, inj, sentinel=SentinelConfig(
        canary_every=1000, shadow_rate=1.0, shadow_sync=False))
    eng.sentinel._golden.clear()
    try:
        for _ in range(4):
            eng.infer(x)
        eng.sentinel.drain()                         # join queue + apply
        assert eng.health()["state"] == "quarantined"
        assert eng.metrics.counter("shadow_requests") >= 1
    finally:
        eng.sentinel.close()


def test_quantized_rung_does_not_false_trip_against_fp32_oracle(jedi8):
    """int8 live vs fp32 terminal differ by real quantization loss; the
    golden-calibrated threshold must absorb it (no trips, no
    quarantine) on a fault-free engine."""
    cfg, params, x, _ = jedi8
    eng = _engine(jedi8, forward="int8_fused_full",
                  sentinel=SentinelConfig(canary_every=2, shadow_rate=0.5,
                                          shadow_sync=True))
    for _ in range(8):
        eng.infer(x)
    h = eng.health()
    assert h["state"] == "healthy"
    assert "shadow_disagreements" not in h["counters"]
    assert "canary_mismatches" not in h["counters"]
    assert h["counters"]["shadow_requests"] >= 2


# -- health surface -------------------------------------------------------


def test_health_reports_sentinel_detail(jedi8):
    eng = _engine(jedi8)
    eng.infer(jedi8[2])
    h = eng.health()
    s = h["sentinel"]
    assert s["canary_every"] == 4 and s["promote_after"] == 2
    assert s["golden_rungs"] == [0, 1]               # fused_full, sr_split
    b = h["buckets"][eng.bucket_for(5)]
    assert {"quarantined", "quarantined_path", "clean_canaries"} <= set(b)


def test_health_state_ordering_quarantined_beats_shedding(jedi8):
    cfg, params, x, _ = jedi8
    inj = FaultInjector()
    inj.arm("weight_corrupt", path="fused_full", factor=8.0)
    eng = _engine(jedi8, inj)
    eng.infer(x)                                     # -> quarantined
    eng.infer(x, deadline=eng._clock() - 1.0)        # -> a recent shed
    assert eng.metrics.counter("shed_requests") == 1
    assert eng.health()["state"] == "quarantined"


# -- metrics thread-safety (satellite) ------------------------------------


def test_metrics_concurrent_increments_lose_nothing():
    """The sentinel's shadow worker increments counters concurrently
    with the serve thread; the metrics lock must make every increment
    land (Counter.__iadd__ is read-modify-write)."""
    m = ServingMetrics()
    n_threads, n_incr = 8, 2000

    def pump():
        for _ in range(n_incr):
            m.incr("shadow_requests")
            m.gauge("inflight", 1.0)

    threads = [threading.Thread(target=pump) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.counter("shadow_requests") == n_threads * n_incr
    assert m.gauge_max("inflight") == 1.0
