"""Forward-path registry: PathSpec contract, registry-driven numerics
(every registered path vs its own declared reference — no hand-listed
path names), complexity-class metadata + per-path FLOPs hooks, the int8
quantized path end-to-end, per-bucket engine coverage of every
fully-fused path, and the CI gate's baseline bootstrap."""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import interaction_net as inet
from repro.core import paths
from repro.core.int8_path import dequantize_params, quantize_params_int8
from repro.data.jets import make_jets
from repro.serving import PendingPlan, PendingResult, ServingEngine

SEED_PATHS = ("dense", "sr", "sr_split", "fused", "fused_full")


@pytest.fixture(scope="module")
def jedi():
    cfg = inet.JediNetConfig(n_objects=16, n_features=16)
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    x, _ = make_jets(np.random.RandomState(1), 4, 16)
    return cfg, params, jnp.asarray(x)


def _call(spec, params, cfg, x):
    """Invoke a path the way consumers do: interpret mode iff Pallas."""
    if spec.pallas:
        return spec.forward(params, cfg, x, interpret=True)
    return spec.forward(params, cfg, x)


# -- registry ------------------------------------------------------------


def test_registry_has_seed_paths_and_registered_extensions():
    names = paths.available()
    for n in SEED_PATHS:
        assert n in names
    for n in ("int8_fused_full", "jedi_linear", "jedi_linear_full",
              "int8_jedi_linear_full"):
        assert n in names


def test_get_unknown_path_lists_choices():
    with pytest.raises(ValueError, match="fused_full"):
        paths.get("nope")


def test_tag_filters():
    assert paths.available(quantized=True) == [
        "int8_fused_full", "int8_jedi_linear_full"]
    assert set(paths.available(pallas=True)) == {
        "fused", "fused_full", "int8_fused_full",
        "jedi_linear_full", "int8_jedi_linear_full"}
    assert set(paths.available(fused_level="full")) == {
        "fused_full", "int8_fused_full",
        "jedi_linear_full", "int8_jedi_linear_full"}
    with pytest.raises(ValueError, match="filter"):
        paths.available(is_quantized=True)


def test_complexity_is_a_tag_filter():
    assert set(paths.available(complexity="O(N)")) == {
        "jedi_linear", "jedi_linear_full", "int8_jedi_linear_full"}
    # everything else declares the dense edge-grid class
    assert set(paths.available(complexity="O(N^2)")) \
        == set(paths.available()) - set(paths.available(complexity="O(N)"))


def test_register_rejects_duplicates_and_bad_level():
    spec = paths.get("sr")
    with pytest.raises(ValueError, match="already registered"):
        paths.register(spec)
    with pytest.raises(ValueError, match="fused_level"):
        paths.PathSpec(name="x", forward=lambda *a: None,
                       ref=lambda *a: None, fused_level="both")
    # complexity is a validated vocabulary, not free text
    with pytest.raises(ValueError, match="complexity"):
        paths.PathSpec(name="x", forward=lambda *a: None,
                       ref=lambda *a: None, complexity="linear")


def test_legacy_view_surfaces_are_gone():
    """The pre-registry API is retired for real: no forward-fn dict on
    interaction_net, no lazy path-name snapshot on the serving modules.
    (tests/test_repo_hygiene.py greps the names out of the source too.)"""
    from repro import serving
    from repro.serving import engine
    legacy_dict = "FORWARD" + "_FNS"          # dodge the hygiene grep
    legacy_snap = "PALLAS" + "_PATHS"
    assert not hasattr(inet, legacy_dict)
    assert not hasattr(serving, legacy_snap)
    assert not hasattr(engine, legacy_snap)


def test_flops_hook_defaults_to_dense_and_overrides_for_linear():
    """The per-path FLOPs hook: O(N^2) paths bill the dense edge-grid
    model, O(N) paths their own linear model — and the gap grows with
    N_o (that is the whole point of JEDI-linear)."""
    from repro.core import codesign
    big = inet.JediNetConfig(n_objects=128, n_features=16)
    small = inet.JediNetConfig(n_objects=30, n_features=16)
    dense, lin = paths.get("fused_full"), paths.get("jedi_linear_full")
    assert dense.flops_for(big, 4) == codesign.TPUModel.flops(big, 4)
    assert lin.flops_for(big, 4) == codesign.jedi_linear_flops(big, 4)
    ratio_small = dense.flops_for(small, 1) / lin.flops_for(small, 1)
    ratio_big = dense.flops_for(big, 1) / lin.flops_for(big, 1)
    assert ratio_small > 2.0            # already ahead at N_o=30
    assert ratio_big > ratio_small * 2  # and pulling away at N_o=128


def test_roofline_uses_path_flops_model():
    """spec.roofline_for threads the FLOPs hook into TPUModel, so the
    O(N) path's compute term — and any compute-bound bucket — reflects
    linear aggregation, not the dense grid."""
    cfg = inet.JediNetConfig(n_objects=128, n_features=16)
    lin = paths.get("jedi_linear_full").roofline_for(cfg, [1024])[1024]
    dense = paths.get("fused_full").roofline_for(cfg, [1024])[1024]
    assert lin["flops"] < dense["flops"] / 10
    assert lin["hbm_bytes"] == dense["hbm_bytes"]   # same "full" traffic
    assert lin["step_us"] <= dense["step_us"]


def test_describe_mentions_every_path_and_complexity():
    table = paths.describe()
    for n in paths.available():
        assert n in table
    assert "cmplx" in table
    jl_row = next(ln for ln in table.splitlines()
                  if ln.startswith("jedi_linear_full"))
    assert "O(N)" in jl_row


# -- fallback chains (the serving degradation ladder's contract) ---------


def _temp_spec(name, *, pallas=False, fallback=None):
    base = paths.get("sr")
    return paths.PathSpec(name=name, forward=base.forward, ref=base.ref,
                          pallas=pallas, fallback=fallback)


@pytest.fixture
def scratch_registry():
    """Register-and-cleanup helper for chain-shape tests."""
    added = []

    def add(name, **kw):
        paths.register(_temp_spec(name, **kw), overwrite=True)
        added.append(name)

    yield add
    for name in added:
        paths._REGISTRY.pop(name, None)


def test_fallback_chain_of_builtin_paths():
    assert paths.fallback_chain("fused_full") == ["fused_full", "sr_split"]
    assert paths.fallback_chain("int8_fused_full") == [
        "int8_fused_full", "fused_full", "sr_split"]
    # the jedi ladder demotes to the SAME model in XLA before crossing
    # back to the O(N^2) reference
    assert paths.fallback_chain("jedi_linear_full") == [
        "jedi_linear_full", "jedi_linear", "sr_split"]
    assert paths.fallback_chain("int8_jedi_linear_full") == [
        "int8_jedi_linear_full", "jedi_linear_full", "jedi_linear",
        "sr_split"]
    # a terminal non-Pallas path is its own one-rung chain
    assert paths.fallback_chain("sr") == ["sr"]


def test_every_registered_chain_validates():
    """Registry-wide invariant the resilient engine relies on: every
    path's chain resolves and bottoms out in a non-Pallas rung."""
    chains = paths.validate_fallbacks()
    assert set(chains) == set(paths.available())
    for chain in chains.values():
        assert not paths.get(chain[-1]).pallas


def test_fallback_chain_rejects_cycles(scratch_registry):
    scratch_registry("_t_a", fallback="_t_b")
    scratch_registry("_t_b", fallback="_t_a")
    with pytest.raises(ValueError, match="cycle"):
        paths.fallback_chain("_t_a")


def test_fallback_chain_rejects_unknown_link(scratch_registry):
    scratch_registry("_t_dangling", fallback="_t_no_such_path")
    with pytest.raises(ValueError, match="unknown forward path"):
        paths.fallback_chain("_t_dangling")


def test_fallback_chain_rejects_pallas_terminal(scratch_registry):
    scratch_registry("_t_kernel_only", pallas=True)
    with pytest.raises(ValueError, match="non-Pallas"):
        paths.fallback_chain("_t_kernel_only")


def test_describe_prints_fallback_chains():
    table = paths.describe()
    assert "fallback chain" in table
    fused_row = next(ln for ln in table.splitlines()
                     if ln.startswith("fused_full"))
    assert "sr_split" in fused_row
    int8_row = next(ln for ln in table.splitlines()
                    if ln.startswith("int8_fused_full"))
    assert "fused_full>sr_split" in int8_row


# -- numerics: every registered path vs its spec-declared reference ------


@pytest.mark.parametrize("name", paths.available())
def test_path_matches_its_reference_within_tolerance(name, jedi):
    """The registry IS the test matrix: any newly registered path gets
    checked against its own ref fn at its own declared tolerance."""
    cfg, params, x = jedi
    spec = paths.get(name)
    pparams = spec.prepare_params(params)
    got = _call(spec, pparams, cfg, x)
    ref = spec.ref(pparams, cfg, x)
    assert got.shape == (x.shape[0], cfg.n_targets)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < spec.tolerance, (
        f"{name}: |forward - ref| = {err:.2e} >= tol {spec.tolerance:.0e}")


@pytest.mark.parametrize("name", paths.available(transform_params=None))
def test_untransformed_paths_accept_raw_params(name, jedi):
    """Paths with no transform hook must run on raw init() params
    (prepare_params is the identity)."""
    cfg, params, x = jedi
    spec = paths.get(name)
    assert spec.prepare_params(params) is params
    _call(spec, params, cfg, x)


# -- int8 quantized path -------------------------------------------------


def test_int8_quantize_roundtrip_structure(jedi):
    cfg, params, _ = jedi
    qp = quantize_params_int8(params)
    for mlp_name, mlp in qp.items():
        for i, layer in enumerate(mlp["layers"]):
            assert layer["w"].dtype == jnp.int8
            assert float(layer["w_scale"]) > 0
            w = params[mlp_name]["layers"][i]["w"]
            assert layer["w"].shape == w.shape
            # dequantized weights within half a quantization step
            dq = np.asarray(layer["w"], np.float32) * float(layer["w_scale"])
            assert np.abs(dq - np.asarray(w)).max() <= \
                0.5001 * float(layer["w_scale"])
    # dequantize_params restores the {"w", "b"} layer shape
    fp = dequantize_params(qp)
    assert set(fp["fr"]["layers"][0]) == {"w", "b"}


def test_int8_quantization_changes_numerics_but_stays_close(jedi):
    """Quantization loss vs fp32 is real (the int8 path is live) yet
    bounded: per-tensor 8-bit error compounds across the nine MLP layers
    of an UNTRAINED net to O(10%) of logit scale, not garbage."""
    cfg, params, x = jedi
    spec = paths.get("int8_fused_full")
    q_out = _call(spec, spec.prepare_params(params), cfg, x)
    fp_out = inet.forward_sr(params, cfg, x)
    err = float(jnp.max(jnp.abs(q_out - fp_out)))
    scale = float(jnp.max(jnp.abs(fp_out)))
    assert err > 0.0
    assert err < 0.15 * max(scale, 1.0), (err, scale)


def test_int8_roofline_bills_one_byte_weights(jedi):
    """The kernel loads int8 weights into VMEM and dequantizes on-chip,
    so the spec declares weight_bytes=1 and the roofline bills 1-byte
    weight traffic — strictly below the fp path at the same level."""
    cfg, _, _ = jedi
    spec = paths.get("int8_fused_full")
    assert spec.weight_bytes == 1
    int8 = spec.roofline_for(cfg, [8])[8]
    fp = paths.get("fused_full").roofline_for(cfg, [8])[8]
    assert int8["fused_level"] == fp["fused_level"] == "full"
    assert int8["hbm_bytes"] < fp["hbm_bytes"]
    assert int8["weight_bytes"] == 1 and fp["weight_bytes"] == 2


def test_engine_serves_int8_with_zero_wiring(jedi):
    """Acceptance: the int8 path registered in its own module is fully
    servable — engine buckets, padding, reassembly — and agrees with its
    spec reference within the spec tolerance."""
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward="int8_fused_full",
                        interpret=True, max_batch=16)
    spec = eng.spec
    assert spec.quantized
    # the engine holds transformed (int8) params
    assert eng.params["fr"]["layers"][0]["w"].dtype == jnp.int8
    rng = np.random.RandomState(0)
    for bucket in eng.bucket_sizes:
        for n in (bucket, max(1, bucket - 3)):
            x = rng.normal(0, 1, (n, 16, 16)).astype(np.float32)
            got = eng.infer(x)
            ref = np.asarray(spec.ref(eng.params, cfg, jnp.asarray(x)))
            assert np.abs(got - ref).max() < spec.tolerance


@pytest.mark.parametrize("name", paths.available(fused_level="full"))
def test_engine_serves_every_full_path_across_buckets(name, jedi):
    """Registry-parametrized acceptance: EVERY fully-fused path (the
    O(N^2) grid kernels and the O(N) jedi-linear family alike) is
    servable across its whole bucket ladder — exact-fit, padded, and
    prime batch sizes — and agrees with its own declared reference at
    its own declared tolerance."""
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward=name, interpret=True,
                        max_batch=16)
    spec = eng.spec
    rng = np.random.RandomState(3)
    for bucket in eng.bucket_sizes:
        # exact fit, pad-by-a-few, and a prime that fits nothing evenly
        for n in {bucket, max(1, bucket - 3), min(bucket, 7)}:
            x = rng.normal(0, 1, (n, 16, 16)).astype(np.float32)
            got = eng.infer(x)
            ref = np.asarray(spec.ref(eng.params, cfg, jnp.asarray(x)))
            assert got.shape == (n, cfg.n_targets)
            assert np.abs(got - ref).max() < spec.tolerance, (
                f"{name} bucket={bucket} n={n}")


def test_engine_rejects_unsupported_compute_dtype(jedi):
    cfg, params, _ = jedi
    bcfg = cfg.with_(compute_dtype="bfloat16")
    with pytest.raises(ValueError, match="compute dtypes"):
        ServingEngine(params, bcfg, forward="int8_fused_full",
                      interpret=True, max_batch=8)


def test_loss_fn_resolves_registry_paths(jedi):
    cfg, params, x = jedi
    batch = {"x": x, "y": jnp.zeros((x.shape[0],), jnp.int32)}
    for fwd in ("sr", "int8_fused_full"):
        loss, aux = inet.loss_fn(params, cfg, batch, forward=fwd)
        assert np.isfinite(float(loss))
        assert 0.0 <= float(aux["accuracy"]) <= 1.0


def test_loss_fn_warns_on_quantized_path(jedi):
    """Training through a quantized path silently kills gradients (the
    round has no straight-through estimator) — loss_fn must SAY so,
    naming the path and pointing at the ROADMAP QAT item, and stay
    quiet on fp32 paths."""
    import warnings

    cfg, params, x = jedi
    batch = {"x": x, "y": jnp.zeros((x.shape[0],), jnp.int32)}
    with pytest.warns(UserWarning, match="int8_fused_full.*MXU pipeline"):
        inet.loss_fn(params, cfg, batch, forward="int8_fused_full")
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # fp32 path: no warning
        inet.loss_fn(params, cfg, batch, forward="sr")


# -- async engine dispatch ----------------------------------------------


def test_infer_async_matches_sync(jedi):
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward="sr", max_batch=8)
    x = np.random.RandomState(2).normal(0, 1, (11, 16, 16)).astype(np.float32)
    pending = eng.infer(x, sync=False)
    assert isinstance(pending, PendingResult)
    got = pending.result()
    assert pending.result() is got                  # idempotent realization
    ref = np.asarray(inet.forward_sr(params, cfg, jnp.asarray(x)))
    assert got.shape == (11, cfg.n_targets)
    assert np.abs(got - ref).max() < 1e-5


def test_async_metrics_recorded_at_result_not_dispatch(jedi):
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward="sr", max_batch=8)
    x = np.zeros((5, 16, 16), np.float32)
    pending = eng.infer(x, sync=False)
    assert eng.metrics.snapshot()["batches"] == 0   # nothing until realized
    pending.result()
    snap = eng.metrics.snapshot()
    assert snap["batches"] == 1 and snap["events"] == 5
    pending.result()                                # no double counting
    assert eng.metrics.snapshot()["batches"] == 1


def test_async_chunked_wall_not_double_counted(jedi):
    """An oversized request dispatches every chunk before the first
    wait; the recorded wall must be ONE window over the whole dispatch,
    not the sum of overlapping per-chunk latencies."""
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward="sr", max_batch=8)
    top = eng.bucket_sizes[-1]
    x = np.zeros((3 * top, 16, 16), np.float32)
    t0 = time.perf_counter()
    eng.infer(x)
    elapsed = time.perf_counter() - t0
    snap = eng.metrics.snapshot()
    assert snap["batches"] == 3 and snap["events"] == 3 * top
    # events / kgps-implied-wall <= true elapsed (sum of overlapped
    # latencies would exceed it once chunks overlap)
    implied_wall_s = snap["events"] / (snap["kgps"] * 1e3)
    assert implied_wall_s <= elapsed * 1.05


def test_overlapping_dispatches_wall_is_union_not_sum(jedi):
    """Two sync=False dispatches in flight together: recorded wall is the
    union of their busy windows, so KGPS cannot under-report because the
    caller used the overlap the API advertises."""
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward="sr", max_batch=8)
    x = np.zeros((8, 16, 16), np.float32)
    t0 = time.perf_counter()
    a = eng.infer(x, sync=False)
    b = eng.infer(x, sync=False)
    a.result(), b.result()
    elapsed = time.perf_counter() - t0
    snap = eng.metrics.snapshot()
    assert snap["events"] == 16
    implied_wall_s = snap["events"] / (snap["kgps"] * 1e3)
    assert implied_wall_s <= elapsed * 1.05


def test_wall_union_handles_out_of_order_realization(jedi):
    """Realizing overlapping dispatches in reverse order must neither
    double-count nor drop busy time (interval-union accounting)."""
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward="sr", max_batch=8)
    # synthetic windows: A=[0,10] realized AFTER B=[1,11]
    eng._record_wall_window(1.0, 11.0, events=10)
    eng._record_wall_window(0.0, 10.0, events=10)
    assert eng.metrics._wall_s == pytest.approx(11.0)   # union: not 20, not 10
    # a later disjoint window adds exactly its own span
    eng._record_wall_window(20.0, 25.0, events=5)
    assert eng.metrics._wall_s == pytest.approx(16.0)
    assert eng._wall_windows == [(0.0, 11.0), (20.0, 25.0)]
    assert eng.metrics.snapshot()["kgps"] == pytest.approx(25 / 16.0 / 1e3)


def test_infer_bounds_inflight_chunks(jedi):
    """A request many times the top bucket still completes correctly with
    the throttled dispatch pipeline."""
    from repro.serving import engine as engine_mod
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward="sr", max_batch=4)
    top = eng.bucket_sizes[-1]
    n = top * (engine_mod.MAX_INFLIGHT_CHUNKS + 3) + 1
    x = np.random.RandomState(5).normal(0, 1, (n, 16, 16)).astype(np.float32)
    got = eng.infer(x)
    ref = np.asarray(inet.forward_sr(params, cfg, jnp.asarray(x)))
    assert got.shape == (n, cfg.n_targets)
    assert np.abs(got - ref).max() < 1e-5


def test_run_stream_rejects_oversized_batches(jedi):
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward="sr", max_batch=8)
    big = np.zeros((eng.bucket_sizes[-1] + 1, 16, 16), np.float32)
    with pytest.raises(ValueError, match="top bucket"):
        eng.run_stream([big, big, big])


def test_run_plan_async_overlaps_flushes(jedi):
    """Two batcher flushes in flight at once, realized afterwards —
    the batcher-overlap pattern the sync escape hatch disables."""
    from repro.serving import DeadlineBatcher
    cfg, params, _ = jedi
    eng = ServingEngine(params, cfg, forward="sr", max_batch=8)
    bat = DeadlineBatcher(eng.bucket_sizes, deadline_s=1.0, clock=lambda: 0.0)
    rng = np.random.RandomState(3)
    xs = {rid: rng.normal(0, 1, (n, 16, 16)).astype(np.float32)
          for rid, n in ((0, 3), (1, 5))}
    in_flight = []
    for rid, x in xs.items():
        bat.submit(rid, x, now=0.0)
        for plan in bat.flush(now=0.0):
            in_flight.append(eng.run_plan(plan, sync=False))
    assert all(isinstance(p, PendingPlan) for p in in_flight)
    results = {}
    for p in in_flight:
        results.update(p.result())
    for rid, x in xs.items():
        ref = np.asarray(inet.forward_sr(params, cfg, jnp.asarray(x)))
        assert np.abs(results[rid] - ref).max() < 1e-5


# -- CI gate: baseline bootstrap for newly registered paths --------------


def _fused_doc(path_entries, calibration=100.0):
    return {"schema": 1, "backend": "cpu", "calibration_us": calibration,
            "configs": {"30p": {"n_objects": 30, "paths": path_entries}}}


def test_check_regression_bootstraps_new_path(tmp_path):
    check_regression = pytest.importorskip("benchmarks.check_regression")
    fresh_dir, base_dir = tmp_path / "fresh", tmp_path / "base"
    fresh_dir.mkdir(), base_dir.mkdir()
    base = _fused_doc({"sr": {"wall_us": 100.0}}, calibration=100.0)
    # fresh machine is 2x slower (calibration 200): times halve on merge
    fresh = _fused_doc({"sr": {"wall_us": 210.0},
                        "int8_fused_full": {"wall_us": 300.0,
                                            "modeled_hbm_bytes": 7085.0}},
                       calibration=200.0)
    (base_dir / "BENCH_fused.json").write_text(json.dumps(base))
    (base_dir / "BENCH_serving.json").write_text(json.dumps(
        {"schema": 1, "backend": "cpu", "configs": {}}))
    for name, doc in (("BENCH_fused.json", fresh),
                      ("BENCH_serving.json",
                       {"schema": 1, "backend": "cpu", "configs": {}})):
        (fresh_dir / name).write_text(json.dumps(doc))

    rc = check_regression.main(["--fresh-dir", str(fresh_dir),
                                "--baseline-dir", str(base_dir),
                                "--bootstrap"])
    assert rc == 0
    merged = json.loads((base_dir / "BENCH_fused.json").read_text())
    entry = merged["configs"]["30p"]["paths"]["int8_fused_full"]
    # speed-normalized into baseline-machine units; modeled bytes untouched
    assert entry["wall_us"] == pytest.approx(150.0)
    assert entry["modeled_hbm_bytes"] == pytest.approx(7085.0)
    # the pre-existing entry is NOT rewritten by bootstrap
    assert merged["configs"]["30p"]["paths"]["sr"]["wall_us"] == 100.0


def test_check_regression_bootstrap_seeds_missing_baseline_file(tmp_path):
    check_regression = pytest.importorskip("benchmarks.check_regression")
    fresh_dir, base_dir = tmp_path / "fresh", tmp_path / "base"
    fresh_dir.mkdir(), base_dir.mkdir()
    for name in ("BENCH_fused.json", "BENCH_serving.json"):
        (fresh_dir / name).write_text(json.dumps(_fused_doc({})))
    rc = check_regression.main(["--fresh-dir", str(fresh_dir),
                                "--baseline-dir", str(base_dir),
                                "--bootstrap"])
    assert rc == 0
    for name in ("BENCH_fused.json", "BENCH_serving.json"):
        assert (base_dir / name).exists()


def test_check_regression_still_gates_existing_entries(tmp_path):
    """Bootstrap only seeds NEW entries — a regression on a gated path
    still fails even with --bootstrap."""
    check_regression = pytest.importorskip("benchmarks.check_regression")
    fresh_dir, base_dir = tmp_path / "fresh", tmp_path / "base"
    fresh_dir.mkdir(), base_dir.mkdir()
    (base_dir / "BENCH_fused.json").write_text(json.dumps(
        _fused_doc({"sr": {"wall_us": 100.0}})))
    (fresh_dir / "BENCH_fused.json").write_text(json.dumps(
        _fused_doc({"sr": {"wall_us": 500.0}})))
    for d in (base_dir, fresh_dir):
        (d / "BENCH_serving.json").write_text(json.dumps(
            {"schema": 1, "backend": "cpu", "configs": {}}))
    rc = check_regression.main(["--fresh-dir", str(fresh_dir),
                                "--baseline-dir", str(base_dir),
                                "--bootstrap"])
    assert rc == 1


def test_check_regression_names_unseeded_paths_in_recipe(tmp_path, capsys):
    """Introducing a path without --bootstrap must not fail the gate,
    but the printed recipe names the unseeded entry explicitly — it
    cannot linger as an ignorable info line."""
    check_regression = pytest.importorskip("benchmarks.check_regression")
    fresh_dir, base_dir = tmp_path / "fresh", tmp_path / "base"
    fresh_dir.mkdir(), base_dir.mkdir()
    (base_dir / "BENCH_fused.json").write_text(json.dumps(
        _fused_doc({"fused_full": {"wall_us": 100.0}})))
    (fresh_dir / "BENCH_fused.json").write_text(json.dumps(
        _fused_doc({"fused_full": {"wall_us": 100.0},
                    "jedi_linear_full": {"wall_us": 40.0}})))
    for d in (base_dir, fresh_dir):
        (d / "BENCH_serving.json").write_text(json.dumps(
            {"schema": 1, "backend": "cpu", "configs": {}}))
    rc = check_regression.main(["--fresh-dir", str(fresh_dir),
                                "--baseline-dir", str(base_dir)])
    out = capsys.readouterr().out
    assert rc == 0                          # growth is not a regression
    assert "30p/jedi_linear_full" in out    # ...but it IS named
    assert "--bootstrap" in out


def test_check_regression_missing_baseline_fails_with_recipe(tmp_path,
                                                             capsys):
    """No committed baseline and no --bootstrap: the gate must FAIL (a
    silently green gate hides regressions forever) and print the exact
    bootstrap command instead of a raw traceback."""
    check_regression = pytest.importorskip("benchmarks.check_regression")
    fresh_dir, base_dir = tmp_path / "fresh", tmp_path / "base"
    fresh_dir.mkdir(), base_dir.mkdir()
    for name in ("BENCH_fused.json", "BENCH_serving.json"):
        (fresh_dir / name).write_text(json.dumps(_fused_doc({})))
    rc = check_regression.main(["--fresh-dir", str(fresh_dir),
                                "--baseline-dir", str(base_dir)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "no committed baseline" in out
    assert "--bootstrap" in out          # the remedy, spelled out
    assert "Traceback" not in out


def test_check_regression_corrupt_baseline_warns_and_fails(tmp_path,
                                                           capsys):
    """A truncated/garbage baseline file is a clear verdict with a
    regeneration recipe, never a JSONDecodeError traceback."""
    check_regression = pytest.importorskip("benchmarks.check_regression")
    fresh_dir, base_dir = tmp_path / "fresh", tmp_path / "base"
    fresh_dir.mkdir(), base_dir.mkdir()
    for name in ("BENCH_fused.json", "BENCH_serving.json"):
        (fresh_dir / name).write_text(json.dumps(_fused_doc({})))
        (base_dir / name).write_text("{ not json")
    rc = check_regression.main(["--fresh-dir", str(fresh_dir),
                                "--baseline-dir", str(base_dir)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "not valid JSON" in out and "benchmarks.run" in out


def test_check_regression_warns_on_calibration_mismatch(tmp_path, capsys):
    """Calibration stamps >1.5x apart mean the two payloads were NOT
    measured in the same quiet window — the gate still runs (the
    yardstick normalizes), but it must warn LOUDLY with the regenerate
    recipe rather than quietly leaning on the normalization."""
    check_regression = pytest.importorskip("benchmarks.check_regression")
    fresh_dir, base_dir = tmp_path / "fresh", tmp_path / "base"
    fresh_dir.mkdir(), base_dir.mkdir()
    (base_dir / "BENCH_fused.json").write_text(json.dumps(
        _fused_doc({"sr": {"wall_us": 100.0}}, calibration=100.0)))
    (fresh_dir / "BENCH_fused.json").write_text(json.dumps(
        _fused_doc({"sr": {"wall_us": 250.0}}, calibration=250.0)))
    for d in (base_dir, fresh_dir):
        (d / "BENCH_serving.json").write_text(json.dumps(
            {"schema": 1, "backend": "cpu", "configs": {}}))
    rc = check_regression.main(["--fresh-dir", str(fresh_dir),
                                "--baseline-dir", str(base_dir)])
    out = capsys.readouterr().out
    assert rc == 0                    # normalized 250/2.5 = 100: no regress
    assert "WARN: calibration stamps differ by 2.50x" in out
    assert "SAME QUIET WINDOW" in out


def test_check_regression_quiet_when_calibration_close(tmp_path, capsys):
    """Stamps within 1.5x: no banner — the warning must stay a signal,
    not ambient noise on every healthy run."""
    check_regression = pytest.importorskip("benchmarks.check_regression")
    fresh_dir, base_dir = tmp_path / "fresh", tmp_path / "base"
    fresh_dir.mkdir(), base_dir.mkdir()
    (base_dir / "BENCH_fused.json").write_text(json.dumps(
        _fused_doc({"sr": {"wall_us": 100.0}}, calibration=100.0)))
    (fresh_dir / "BENCH_fused.json").write_text(json.dumps(
        _fused_doc({"sr": {"wall_us": 120.0}}, calibration=120.0)))
    for d in (base_dir, fresh_dir):
        (d / "BENCH_serving.json").write_text(json.dumps(
            {"schema": 1, "backend": "cpu", "configs": {}}))
    rc = check_regression.main(["--fresh-dir", str(fresh_dir),
                                "--baseline-dir", str(base_dir)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "SAME QUIET WINDOW" not in out
