"""Event-loop front-end (serving/loop.py) + LM fabric (serving/lm.py).

Covers the fabric's live-serving contracts: loop-served logits match
direct engine inference, deadline shedding under backlog, backpressure
bounds the in-flight window, out-of-order plan completion delivers to
the right futures, and the LM port's slot-recycling decode reproduces
the pre-refactor ``launch/serve.py`` greedy token streams exactly
(including a single prefill compile across mixed prompt lengths).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.interaction_net import JediNetConfig, init
from repro.serving import (
    LMEngine,
    RequestFuture,
    ResilientEngine,
    ServingLoop,
    ServingMetrics,
)
from repro.serving.lm import prompt_bucket_ladder, tiny_config


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def jedi8():
    cfg = JediNetConfig(n_objects=8, n_features=4)
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs.registry import get_arch
    from repro.models import transformer as tfm
    cfg = tiny_config(get_arch("h2o-danube-1.8b").model)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params, tfm


# -- numerics: loop-served == direct infer ----------------------------------


def test_loop_matches_direct_infer(jedi8):
    cfg, params = jedi8
    eng = ResilientEngine(params, cfg, forward="sr_split",
                          bucket_sizes=[4, 8])
    loop = ServingLoop(eng, deadline_s=1e9, max_inflight=2)
    rng = np.random.default_rng(0)
    xs = [rng.normal(size=(n, 8, 4)).astype(np.float32)
          for n in (3, 5, 2, 8, 1)]
    futs = [loop.submit(x) for x in xs]
    loop.drain()
    assert loop.idle
    for fut, x in zip(futs, xs):
        assert fut.done and not fut.shed
        out = fut.result()
        assert out.shape[0] == x.shape[0]
        np.testing.assert_allclose(out, eng.infer(x), rtol=1e-5, atol=1e-6)
    assert eng.metrics.counter("loop_requests") == len(xs)
    assert eng.metrics.counter("loop_completed") == len(xs)


def test_loop_request_split_across_plans_reassembles(jedi8):
    cfg, params = jedi8
    eng = ResilientEngine(params, cfg, forward="sr_split", bucket_sizes=[4])
    loop = ServingLoop(eng, deadline_s=1e9)
    rng = np.random.default_rng(1)
    # 10 events through a 4-bucket ladder: the request straddles 3 plans
    x = rng.normal(size=(10, 8, 4)).astype(np.float32)
    fut = loop.submit(x)
    loop.drain()
    out = fut.result()
    assert out.shape[0] == 10
    np.testing.assert_allclose(out, eng.infer(x), rtol=1e-5, atol=1e-6)


# -- deadline shedding under backlog ----------------------------------------


def test_loop_sheds_expired_requests_under_backlog(jedi8):
    cfg, params = jedi8
    clk = FakeClock()
    eng = ResilientEngine(params, cfg, forward="sr_split",
                          bucket_sizes=[4, 8], clock=clk)
    loop = ServingLoop(eng, deadline_s=0.5, clock=clk)
    rng = np.random.default_rng(2)
    # backlog: the request waits in the batcher past its serve-by budget
    late = loop.submit(rng.normal(size=(2, 8, 4)).astype(np.float32),
                       deadline_s=1.0)
    clk.t += 10.0                       # backlog delay >> deadline
    loop.poll()                         # fuse fires -> dispatch -> shed
    assert late.done and late.shed
    assert late.result() is None
    assert eng.metrics.counter("shed_requests") == 1
    assert eng.metrics.counter("shed_events") == 2
    # a fresh request still serves (shedding is per-request, not global)
    ok = loop.submit(rng.normal(size=(2, 8, 4)).astype(np.float32),
                     deadline_s=1e9)
    loop.drain()
    assert ok.result() is not None


# -- backpressure + out-of-order delivery (deterministic stub engine) -------


class StubHandle:
    def __init__(self, engine, plan):
        self._engine = engine
        self._plan = plan
        self.ready = False

    def result(self):
        self.ready = True
        self._engine.outstanding.remove(self)
        return {rid: np.full((stop - start, 1), float(rid))
                for rid, start, stop in self._plan.requests}


class StubEngine:
    """Engine-shaped test double: handles complete only when told to."""

    def __init__(self, bucket_sizes=(4,)):
        self.bucket_sizes = sorted(bucket_sizes)
        self.metrics = ServingMetrics()
        self.outstanding: list[StubHandle] = []
        self.max_outstanding = 0

    def run_plan(self, plan, *, sync=True):
        assert not sync
        h = StubHandle(self, plan)
        self.outstanding.append(h)
        self.max_outstanding = max(self.max_outstanding,
                                   len(self.outstanding))
        return h


def test_backpressure_bounds_inflight():
    eng = StubEngine(bucket_sizes=[4])
    loop = ServingLoop(eng, deadline_s=1e9, max_inflight=2)
    for i in range(6):                  # 6 full buckets -> 6 plans
        loop.submit(np.zeros((4, 2), np.float32))
    # the loop realized older plans rather than exceeding the window
    assert eng.max_outstanding <= 2
    assert loop.inflight <= 2
    loop.drain()
    assert loop.idle and not eng.outstanding
    assert eng.metrics.gauge_max("inflight_plans") <= 2


def test_out_of_order_completion_delivers_to_right_futures():
    eng = StubEngine(bucket_sizes=[4])
    loop = ServingLoop(eng, deadline_s=1e9, max_inflight=8)
    futs = [loop.submit(np.zeros((4, 2), np.float32)) for _ in range(3)]
    assert len(eng.outstanding) == 3
    # plan 2 (newest) completes first; plan 0 last
    eng.outstanding[2].ready = True
    loop.poll()
    assert futs[2].done and not futs[0].done and not futs[1].done
    np.testing.assert_array_equal(futs[2].result(),
                                  np.full((4, 1), 2.0))
    eng.outstanding[0].ready = True     # plans 0,1 remain; 0 is oldest
    loop.poll()
    assert futs[0].done and not futs[1].done
    np.testing.assert_array_equal(futs[0].result(), np.full((4, 1), 0.0))
    loop.drain()
    np.testing.assert_array_equal(futs[1].result(), np.full((4, 1), 1.0))


def test_future_result_before_done_raises():
    eng = StubEngine(bucket_sizes=[4])
    loop = ServingLoop(eng, deadline_s=1e9)
    fut = loop.submit(np.zeros((4, 2), np.float32))
    with pytest.raises(RuntimeError, match="in flight"):
        fut.result()
    loop.drain()
    fut.result()


def test_loop_gauges_track_queue_and_inflight():
    eng = StubEngine(bucket_sizes=[8])
    loop = ServingLoop(eng, deadline_s=1e9)
    loop.submit(np.zeros((3, 2), np.float32))   # below the bucket: queued
    assert loop.queue_depth == 3
    assert eng.metrics.gauge_value("queue_depth") == 3
    assert eng.metrics.gauge_value("queue_requests") == 1
    loop.submit(np.zeros((5, 2), np.float32))   # fills the bucket: cut
    assert eng.metrics.gauge_max("queue_depth") == 8
    loop.drain()
    assert eng.metrics.gauge_value("queue_depth") == 0
    assert eng.metrics.gauge_value("inflight_plans") == 0


# -- LM fabric ---------------------------------------------------------------


def test_prompt_bucket_ladder():
    assert prompt_bucket_ladder(64) == [16, 32, 64]
    assert prompt_bucket_ladder(100) == [16, 32, 64, 100]
    assert prompt_bucket_ladder(8) == [8]
    with pytest.raises(ValueError):
        prompt_bucket_ladder(0)


def _reference_serve(tfm, cfg, params, prompts, slots, max_seq, max_new):
    """The pre-refactor launch/serve.py loop, inlined verbatim as the
    golden reference for the fabric port's scheduling + numerics."""

    class R:
        def __init__(self, rid, prompt):
            self.rid, self.prompt, self.out = rid, prompt, []

    queue = [R(i, p) for i, p in enumerate(prompts)]
    done = []
    cache = tfm.init_cache(cfg, slots, max_seq)
    slot_req = [None] * slots
    prefill = jax.jit(lambda p, t: tfm.forward(p, cfg, t, return_cache=True))
    decode = jax.jit(lambda p, c, t: tfm.decode_step(p, cfg, c, t))

    def admit(slot, req):
        nonlocal cache
        logits, _, pc = prefill(params, jnp.asarray(req.prompt[None]))
        t, pl = cache["k"].shape[2], req.prompt.shape[0]
        for key in ("k", "v"):
            upd = jnp.zeros_like(cache[key][:, slot])
            upd = upd.at[:, :pl].set(pc[key][:, 0])
            cache[key] = cache[key].at[:, slot].set(upd)
        sp = jnp.full((t,), -1, jnp.int32).at[:pl].set(jnp.arange(pl))
        cache["slot_pos"] = cache["slot_pos"].at[slot].set(sp)
        cache["pos"] = cache["pos"].at[slot].set(pl)
        req.out.append(int(jnp.argmax(logits[0, -1])))
        slot_req[slot] = req

    while queue or any(slot_req):
        for s in range(slots):
            if slot_req[s] is None and queue:
                admit(s, queue.pop(0))
        toks = jnp.asarray([
            (slot_req[s].out[-1] if slot_req[s] else 0)
            for s in range(slots)], jnp.int32)
        logits, cache = decode(params, cache, toks)
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in range(slots):
            req = slot_req[s]
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            if len(req.out) >= max_new:
                done.append(req)
                slot_req[s] = None
    return {r.rid: r.out for r in done}


def test_lm_fabric_reproduces_prerefactor_tokens(lm_setup):
    cfg, params, tfm = lm_setup
    slots, max_seq, max_new = 3, 64, 5
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, pl)
               for pl in (7, 13, 9, 16, 5)]
    ref = _reference_serve(tfm, cfg, params, prompts, slots, max_seq,
                           max_new)
    eng = LMEngine(params, cfg, slots=slots, max_seq=max_seq)
    for p in prompts:
        eng.submit(p, max_new)
    report = eng.run()
    got = {r.rid: r.out for r in report["done"]}
    assert got == ref                   # EXACT greedy token streams


def test_lm_single_prefill_compile_across_mixed_lengths(lm_setup):
    cfg, params, tfm = lm_setup
    eng = LMEngine(params, cfg, slots=2, max_seq=64)
    assert eng.bucket_sizes == [16, 32, 64]
    for pl in (3, 7, 11, 16):           # all pad to the 16 rung
        eng.submit(np.arange(pl) % cfg.vocab_size, 2)
    report = eng.run()
    assert report["prefill_compiles"] == 1
    assert eng.metrics.counter("prefills") == 4
    # a longer prompt earns exactly one more rung
    eng.submit(np.arange(20) % cfg.vocab_size, 2)
    eng.run()
    assert sum(1 for k in eng._cache if k[1] != "decode") == 2


def test_lm_deadline_sheds_queued_requests(lm_setup):
    cfg, params, tfm = lm_setup
    clk = FakeClock()
    eng = LMEngine(params, cfg, slots=1, max_seq=32, clock=clk)
    a = eng.submit(np.arange(4), 3)                       # no deadline
    b = eng.submit(np.arange(5), 3, deadline_s=0.5)       # queued behind a
    clk.t += 10.0                       # b expires while a holds the slot
    report = eng.run()
    assert not a.shed and len(a.out) == 3
    assert b.shed and b.out == []
    assert report["shed"] == 1
    assert eng.health()["state"] == "shedding"
    assert eng.metrics.counter("lm_shed_requests") == 1


def test_lm_rejects_oversized_prompt(lm_setup):
    cfg, params, tfm = lm_setup
    eng = LMEngine(params, cfg, slots=1, max_seq=16)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        eng.submit(np.arange(17), 1)


def test_request_future_partial_shed_is_none():
    fut = RequestFuture(0, 4)
    fut._deliver(0, np.zeros((2, 1)))
    assert not fut.done
    fut._deliver_shed(2)
    assert fut.done and fut.shed
    assert fut.result() is None
