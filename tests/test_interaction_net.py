"""The paper's core: strength reduction + fusion must be exact rewrites
of the dense-MMM baseline (Sec 3.1-3.5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adjacency
from repro.core import interaction_net as inet


CFGS = [
    inet.JediNetConfig(n_objects=4, n_features=3, d_e=5, d_o=6,
                       fr_hidden=(7,), fo_hidden=(7,), phi_hidden=(7,)),
    inet.JediNetConfig(n_objects=30, n_features=16),         # paper 30p
    inet.JediNetConfig(n_objects=50, n_features=16,
                       fr_hidden=(8, 8), fo_hidden=(32,) * 3),  # U4-like
]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: f"No{c.n_objects}")
def test_sr_equals_dense(cfg, key):
    """Strength-reduced path == explicit Rr/Rs MMM baseline."""
    params = inet.init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.n_objects,
                                                  cfg.n_features))
    dense = inet.forward_dense(params, cfg, x)
    sr = inet.forward_sr(params, cfg, x)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: f"No{c.n_objects}")
def test_fused_equals_sr(cfg, key):
    """Pallas-fused path (interpret mode) == strength-reduced path."""
    params = inet.init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.n_objects,
                                                  cfg.n_features))
    sr = inet.forward_sr(params, cfg, x)
    fused = inet.forward_fused(params, cfg, x, interpret=True)
    np.testing.assert_allclose(np.asarray(sr), np.asarray(fused),
                               rtol=2e-3, atol=2e-3)


def test_edge_index_maps_match_dense_matrices():
    """Receiver-major index maps == the one-hot Rr/Rs of Fig 2."""
    for n in (2, 4, 7, 30):
        recv, send = adjacency.edge_index_maps(n)
        rr, rs = adjacency.dense_relation_matrices(n)
        n_e = n * (n - 1)
        assert recv.shape == send.shape == (n_e,)
        # each column of Rr/Rs is one-hot at the indexed row
        np.testing.assert_array_equal(np.argmax(rr, 0), recv)
        np.testing.assert_array_equal(np.argmax(rs, 0), send)
        assert rr.sum() == rs.sum() == n_e
        # no self-edges
        assert np.all(recv != send)


def test_b_matrix_semantics(key):
    """B columns = [receiver features ‖ sender features] (Sec 2.2)."""
    cfg = CFGS[0]
    x = jax.random.normal(key, (1, cfg.n_objects, cfg.n_features))
    b = inet.build_b_matrix(cfg, x)[0]
    recv, send = adjacency.edge_index_maps(cfg.n_objects)
    for e in range(cfg.n_edges):
        np.testing.assert_allclose(b[e, : cfg.n_features], x[0, recv[e]],
                                   rtol=1e-6)
        np.testing.assert_allclose(b[e, cfg.n_features:], x[0, send[e]],
                                   rtol=1e-6)


def test_aggregate_is_mmm3(key):
    """aggregate_incoming == E @ Rr^T on random E (Alg 2 / outer product)."""
    cfg = inet.JediNetConfig(n_objects=6, n_features=3, d_e=4)
    e_cols = jax.random.normal(key, (2, cfg.n_edges, cfg.d_e))
    rr, _ = adjacency.dense_relation_matrices(cfg.n_objects)
    want = jnp.einsum("bed,ne->bnd", e_cols, jnp.asarray(rr))
    got = inet.aggregate_incoming(cfg, e_cols)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got), rtol=1e-5,
                               atol=1e-6)


def test_op_counts_match_fig8():
    """Fig 8: 30p model -> 6960 adds remain for MMM3 (3.3%), 0 for MMM1/2,
    96.7% iteration reduction."""
    c = adjacency.mmm_op_counts(30, 16, 8)
    assert c["n_edges"] == 870
    assert c["mmm12_sr_mults"] == 0 and c["mmm12_sr_adds"] == 0
    assert c["mmm3_sr_mults"] == 0
    assert c["mmm3_sr_adds"] == 8 * 870 == 6960          # Fig 8(b)
    assert c["iterations_sr"] / c["iterations_baseline"] == pytest.approx(
        1 / 30, rel=1e-6)                                # 96.7% reduction


def test_loss_and_grads_finite(key):
    cfg = CFGS[0]
    params = inet.init(key, cfg)
    batch = {
        "x": jax.random.normal(jax.random.PRNGKey(2), (8, cfg.n_objects,
                                                       cfg.n_features)),
        "y": jnp.zeros((8,), jnp.int32),
    }
    for fwd in ("dense", "sr"):
        (loss, _), grads = jax.value_and_grad(
            lambda p: inet.loss_fn(p, cfg, batch, forward=fwd),
            has_aux=True)(params)
        assert np.isfinite(float(loss))
        assert all(np.all(np.isfinite(g)) for g in
                   jax.tree_util.tree_leaves(grads))
