"""Static-analysis subsystem: repo-clean gates + broken-fixture bites.

Two families:

* tier-1 wiring — the full lint pass and the full kernel-contract
  audit report ZERO findings on this repo (the same gate CI's
  ``analysis`` job runs via ``python -m repro.analysis``);
* the auditor must BITE — deliberately broken kernels (bf16
  accumulator, BlockSpec/bytes-model 2x disagreement, partially
  quantized pytree) and broken ladder models each produce findings
  with actionable messages.  A checker that cannot detect the bug
  class it exists for is worse than none.

The mini Pallas kernels below live in a test file, outside
``src/repro/kernels/`` — exactly what the ``pallas-containment`` rule
forbids — so this file is sanctioned in ``analysis.toml``.
"""

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.config import AnalysisConfig, _parse_toml_subset
from repro.analysis.findings import Finding
from repro.analysis.kernel_audit import audit_path, audit_registry
from repro.analysis.lint import run_lint
from repro.analysis.rules import ALL_RULES
from repro.configs.jedi_30p import MODEL as CFG
from repro.core import interaction_net, paths

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def params():
    return interaction_net.init(jax.random.PRNGKey(0), CFG)


# ---------------------------------------------------------------------------
# Repo-clean gates (the tier-1 wiring of `python -m repro.analysis`).
# ---------------------------------------------------------------------------

def test_lint_pass_reports_zero_findings():
    findings = run_lint(REPO, ALL_RULES, AnalysisConfig.load(REPO))
    assert findings == [], "\n".join(f.render() for f in findings)


def test_kernel_audit_reports_zero_findings(params):
    findings = audit_registry(CFG, params, max_batch=1024)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_every_pallas_path_is_audited_at_every_rung(params):
    """The drift check actually covers each Pallas path's whole ladder:
    the residency model must answer (consistently) at every rung."""
    for spec in paths.specs(pallas=True):
        assert spec.residency_model is not None, spec.name
        tparams = spec.prepare_params(params)
        ladder = spec.bucket_ladder(CFG, tparams, 1024)
        assert ladder, spec.name
        for rung in ladder:
            model = spec.residency_model(CFG, tparams, rung)
            assert model["fits"], (spec.name, rung)
            assert model["block_b"] * model["per_sample_bytes"] <= \
                model["effective_budget"], (spec.name, rung)


def test_cli_runs_clean_with_json(capsys):
    from repro.analysis.__main__ import main
    rc = main(["--json", "--root", str(REPO)])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert doc["count"] == 0 and doc["findings"] == []
    assert set(doc["timings"]) == {"lint_s", "audit_s"}


# ---------------------------------------------------------------------------
# Broken-kernel fixtures: a mini Pallas kernel with tunable defects.
# ---------------------------------------------------------------------------

_D_OUT = 16


def _mini_forward(wparams, cfg, x, *, block_b=8, accum_dtype=jnp.float32):
    """One-matmul Pallas 'network': x (B, N_o, P) -> (B, D) logits.
    ``accum_dtype`` poisons the accumulator path when set to bf16."""
    import jax.experimental.pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    w = wparams["w"]
    batch = x.shape[0]
    feat = x.shape[1] * x.shape[2]
    bb = min(block_b, batch)

    def kernel(x_ref, w_ref, o_ref, acc_ref):
        xv = x_ref[...].astype(accum_dtype)
        wv = w_ref[...].astype(accum_dtype)
        acc_ref[...] = jnp.dot(xv, wv, preferred_element_type=accum_dtype)
        o_ref[...] = acc_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(batch // bb,),
        in_specs=[pl.BlockSpec((bb, feat), lambda i: (i, 0)),
                  pl.BlockSpec(w.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bb, _D_OUT), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, _D_OUT), accum_dtype),
        scratch_shapes=[pltpu.VMEM((bb, _D_OUT), accum_dtype)],
    )(x.reshape(batch, feat), w)


def _mini_params():
    feat = CFG.n_objects * CFG.n_features
    return {"w": jnp.zeros((feat, _D_OUT), jnp.float32)}


_MINI_PER_SAMPLE = 8192           # generous upper bound on any live tensor


def _mini_residency(cfg, wparams, batch, *, block_b=8, weight_scale=1.0,
                    fits=True):
    return {"kernel": "mini", "block_b": block_b, "block_s": None,
            "grid": (max(batch, block_b) // block_b,),
            "per_sample_bytes": _MINI_PER_SAMPLE,
            "reserved_bytes": int(wparams["w"].nbytes * weight_scale),
            "effective_budget": 4 * 1024 * 1024,
            "weight_residency_bytes": int(wparams["w"].nbytes * weight_scale),
            "fits": fits}


def _mini_spec(name, forward, residency):
    return paths.PathSpec(
        name=name, forward=forward, ref=forward, fused_level="full",
        pallas=True, complexity="O(N)", fallback=None,
        per_sample_bytes=lambda cfg, p: _MINI_PER_SAMPLE,
        residency_model=residency, description="broken-kernel fixture")


def test_auditor_detects_bf16_accumulator():
    def fwd(p, cfg, x, **kw):
        return _mini_forward(p, cfg, x, accum_dtype=jnp.bfloat16)

    findings = audit_path(_mini_spec("bad_bf16", fwd, _mini_residency),
                          CFG, _mini_params(), max_batch=16)
    rules = {f.rule for f in findings}
    assert "audit-accum-dtype" in rules
    text = "\n".join(f.message for f in findings)
    assert "bfloat16" in text and "float32" in text
    # actionable: says what to change, and names both failure sites
    assert "scratch" in text and "dot_general" in text


def test_auditor_detects_blockspec_bytes_model_2x_disagreement():
    def fwd(p, cfg, x, **kw):
        # kernel tiles at 16; the model below claims 8 — and claims the
        # weights occupy HALF the VMEM their BlockSpec actually asks for
        return _mini_forward(p, cfg, x, block_b=16)

    def residency(cfg, p, batch, **kw):
        return _mini_residency(cfg, p, batch, block_b=8, weight_scale=0.5)

    findings = audit_path(_mini_spec("bad_2x", fwd, residency),
                          CFG, _mini_params(), max_batch=16)
    rules = {f.rule for f in findings}
    assert "audit-tile-mismatch" in rules
    assert "audit-vmem-drift" in rules
    tile = next(f for f in findings if f.rule == "audit-tile-mismatch"
                and "batch tile is 16" in f.message)
    assert "block_b=8" in tile.message
    drift = next(f for f in findings if f.rule == "audit-vmem-drift")
    assert "100% drift" in drift.message


def test_auditor_detects_partially_quantized_pytree(params):
    from repro.core.int8_path import quantize_params_int8

    def half_quantize(p):
        q = quantize_params_int8(p)
        return {"fr": q["fr"], "fo": p["fo"], "phi": p["phi"]}

    spec = dataclasses.replace(paths.get("int8_fused_full"),
                               name="int8_partial",
                               transform_params=half_quantize)
    findings = audit_path(spec, CFG, params, max_batch=64)
    assert any(f.rule == "audit-trace-failure"
               and "partially quantized" in f.message for f in findings), \
        "\n".join(f.render() for f in findings)


def test_auditor_detects_ladder_rung_over_budget():
    def fwd(p, cfg, x, **kw):
        return _mini_forward(p, cfg, x)

    def residency(cfg, p, batch, **kw):
        return _mini_residency(cfg, p, batch, fits=False)

    findings = audit_path(_mini_spec("bad_ladder", fwd, residency),
                          CFG, _mini_params(), max_batch=16)
    assert any(f.rule == "audit-ladder-budget" for f in findings)


def test_auditor_flags_pallas_path_without_residency_model(params):
    spec = dataclasses.replace(paths.get("fused_full"),
                               name="no_model", residency_model=None)
    findings = audit_path(spec, CFG, params, max_batch=64)
    assert [f.rule for f in findings] == ["audit-no-residency-model"]


# ---------------------------------------------------------------------------
# Lint rules bite on synthetic trees.
# ---------------------------------------------------------------------------

def _lint_tmp(tmp_path, rule, config=None):
    return run_lint(tmp_path, [rule], config or AnalysisConfig())


def test_pallas_containment_rule_bites(tmp_path):
    from repro.analysis.rules.pallas_containment import PallasContainmentRule
    (tmp_path / "rogue.py").write_text(
        "import jax.experimental.pallas as pl\n"
        "out = pl.pallas_call(lambda r: None, grid=(1,))\n")
    findings = _lint_tmp(tmp_path, PallasContainmentRule())
    assert [f.rule for f in findings] == ["pallas-containment"]
    assert "src/repro/kernels/" in findings[0].message


def test_wall_clock_rule_distinguishes_seams_from_calls(tmp_path):
    from repro.analysis.rules.wall_clock import WallClockRule
    pkg = tmp_path / "src" / "repro" / "serving"
    pkg.mkdir(parents=True)
    (pkg / "hot.py").write_text(
        "import time\n"
        "from time import perf_counter\n"
        "def step(clock=time.monotonic):   # seam: attribute ref, legal\n"
        "    t0 = clock()\n"
        "    t1 = time.time()              # direct call: finding\n"
        "    t2 = perf_counter()           # direct call: finding\n"
        "    return t1 - t0 + t2\n")
    findings = _lint_tmp(tmp_path, WallClockRule())
    assert sorted(f.line for f in findings) == [5, 6]
    assert all("injectable clock seam" in f.message for f in findings)


def test_register_path_decl_rule_bites(tmp_path):
    from repro.analysis.rules.register_path_decl import RegisterPathDeclRule
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "newpath.py").write_text(
        "from repro.core.paths import register_path\n"
        "@register_path(name='mystery', fused_level='none')\n"
        "def forward_mystery(p, cfg, x):\n"
        "    return x\n")
    findings = _lint_tmp(tmp_path, RegisterPathDeclRule())
    assert [f.rule for f in findings] == ["register-path-decl"]
    assert "complexity" in findings[0].message
    assert "fallback" in findings[0].message


def test_retired_names_rule_honors_analysis_toml_allowlist(tmp_path):
    from repro.analysis.rules.retired_names import RetiredNamesRule
    name = "FORWARD" + "_FNS"
    (tmp_path / "sanctioned.md").write_text(f"history: removed {name}\n")
    (tmp_path / "offender.py").write_text(f"{name} = {{}}\n")
    (tmp_path / "analysis.toml").write_text(
        '[rules.retired-names]\nallow = ["sanctioned.md", "analysis.toml"]\n')
    findings = _lint_tmp(tmp_path, RetiredNamesRule(),
                         AnalysisConfig.load(tmp_path))
    assert [f.location for f in findings] == ["offender.py"]


# ---------------------------------------------------------------------------
# Perf-gate cross-reference: failing baselines name registered paths.
# ---------------------------------------------------------------------------

def test_regression_gate_extracts_path_names_for_audit_hint():
    check_regression = pytest.importorskip("benchmarks.check_regression")
    lines = [
        "BENCH_fused.json: jedi_30p/fused_full: wall_us 10 -> 20 us",
        "BENCH_serving.json: jedi_30p/int8_fused_full/b64: per_event 1 -> 9",
        "BENCH_fused.json: missing fresh file",
    ]
    assert check_regression._failing_path_names(lines) == {
        "fused_full", "int8_fused_full"}


def test_regression_gate_audit_hint_stays_quiet_on_clean_paths(capsys):
    """The hint machinery runs the real auditor on the named paths and
    must not fire (or crash the gate) when their contracts hold."""
    check_regression = pytest.importorskip("benchmarks.check_regression")
    check_regression._audit_hint(
        ["BENCH_fused.json: jedi_30p/fused_full: wall_us 10 -> 20 us"])
    out = capsys.readouterr().out
    assert "NOTE: the kernel-contract auditor" not in out


# ---------------------------------------------------------------------------
# Config loader (incl. the 3.10 no-tomllib fallback parser).
# ---------------------------------------------------------------------------

def test_toml_subset_parser_multiline_arrays_and_comments():
    data = _parse_toml_subset(
        "# header comment\n"
        "[rules.some-rule]\n"
        "allow = [\n"
        '    "a.py",   # trailing comment\n'
        '    "b/*.py",\n'
        "]\n"
        "limit = 5\n"
        "strict = true\n")
    table = data["rules"]["some-rule"]
    assert table["allow"] == ["a.py", "b/*.py"]
    assert table["limit"] == 5 and table["strict"] is True


def test_toml_subset_parser_rejects_garbage():
    with pytest.raises(ValueError):
        _parse_toml_subset("[rules.x]\nallow = {oops}\n")


def test_allowlist_glob_matching():
    cfg = AnalysisConfig(allow={"r": ["docs/*.md", "exact.py"]})
    assert cfg.allowed("r", "docs/notes.md")
    assert cfg.allowed("r", "exact.py")
    assert not cfg.allowed("r", "src/exact.py")


def test_findings_are_json_round_trippable():
    f = Finding(rule="r", location="a.py", line=3, message="m")
    assert json.loads(json.dumps(f.as_dict())) == {
        "rule": "r", "location": "a.py", "line": 3, "message": "m"}
    assert f.render() == "[r] a.py:3: m"
