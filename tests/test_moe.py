"""MoE dispatch semantics: sort-based dispatch == dense one-hot reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib


def dense_moe_reference(params, moe, x, activation="silu"):
    """O(T*E*C) reference: explicit per-expert capacity-respecting one-hot
    dispatch with the same top-k gating + renormalization."""
    t, d = x.shape
    e, k = moe.n_experts, moe.top_k
    c = moe_lib.capacity(t, moe)
    probs = jax.nn.softmax(x @ params["router"]["w"], axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    out = np.zeros((t, d), np.float32)
    fill = np.zeros(e, np.int64)
    xn = np.asarray(x)
    wg = np.asarray(params["experts"]["w_gate"])
    wi = np.asarray(params["experts"]["w_in"])
    wo = np.asarray(params["experts"]["w_out"])
    gv = np.asarray(gate_vals)
    ei = np.asarray(expert_ids)
    # same priority order as the stable argsort over (token, k) pairs
    for tok in range(t):
        for j in range(k):
            ex = int(ei[tok, j])
            if fill[ex] >= c:
                continue
            fill[ex] += 1
            h = xn[tok] @ wg[ex], xn[tok] @ wi[ex]
            act = h[0] * (1.0 / (1.0 + np.exp(-h[0])))  # silu
            y = (act * h[1]) @ wo[ex]
            out[tok] += gv[tok, j] * y
    return out


@pytest.mark.parametrize("t,e,k", [(32, 4, 2), (64, 8, 1), (48, 4, 3)])
def test_moe_matches_dense_reference(t, e, k):
    moe = MoEConfig(n_experts=e, top_k=k, capacity_factor=8.0)  # no drops
    d, ff = 16, 32
    params = moe_lib.init_moe(jax.random.PRNGKey(0), moe, d, ff)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d))
    got, aux = moe_lib.moe_apply(params, moe, x,
                                 compute_dtype=jnp.float32)
    want = dense_moe_reference(params, moe, x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most assignments are dropped, output is
    partial but finite, and no crash."""
    moe = MoEConfig(n_experts=4, top_k=2, capacity_factor=0.1)
    params = moe_lib.init_moe(jax.random.PRNGKey(0), moe, 8, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
    got, aux = moe_lib.moe_apply(params, moe, x, compute_dtype=jnp.float32)
    assert np.all(np.isfinite(np.asarray(got)))
    # some tokens must have received zero expert output
    norms = np.linalg.norm(np.asarray(got), axis=-1)
    assert (norms < 1e-6).any()


def test_moe_grads_flow_to_router_and_experts():
    moe = MoEConfig(n_experts=4, top_k=2)
    params = moe_lib.init_moe(jax.random.PRNGKey(0), moe, 8, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))

    def loss(p):
        y, aux = moe_lib.moe_apply(p, moe, x, compute_dtype=jnp.float32)
        return jnp.mean(jnp.square(y)) + aux

    g = jax.grad(loss)(params)
    assert float(jnp.max(jnp.abs(g["router"]["w"]))) > 0
    assert float(jnp.max(jnp.abs(g["experts"]["w_gate"]))) > 0
    assert all(np.all(np.isfinite(l))
               for l in jax.tree_util.tree_leaves(g))


def test_aux_loss_penalizes_imbalance():
    """A router forced onto one expert has higher aux loss than a uniform
    one."""
    moe = MoEConfig(n_experts=4, top_k=1)
    d = 8
    params = moe_lib.init_moe(jax.random.PRNGKey(0), moe, d, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, d))
    # biased router: all weight on expert 0
    biased = jax.tree_util.tree_map(lambda a: a, params)
    w = np.zeros((d, 4), np.float32)
    w[:, 0] = 5.0
    biased["router"]["w"] = jnp.asarray(w)
    _, aux_biased = moe_lib.moe_apply(biased, moe, x,
                                      compute_dtype=jnp.float32)
    uniform = jax.tree_util.tree_map(lambda a: a, params)
    uniform["router"]["w"] = jnp.zeros((d, 4))
    _, aux_uniform = moe_lib.moe_apply(uniform, moe, x,
                                       compute_dtype=jnp.float32)
    assert float(aux_biased) > float(aux_uniform)
