"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one forward/train step on CPU, asserting output shapes and
no NaNs.  (Full configs are exercised only via the dry-run.)"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, RecsysConfig, \
    TransformerConfig
from repro.configs.registry import ALL_ARCHS, get_arch
from repro.models import recsys as fm_lib
from repro.models import transformer as tfm
from repro.models.gnn import GNN_MODULES


def reduced_lm(cfg: TransformerConfig) -> TransformerConfig:
    """Same family (MoE-ness, SWA, GQA ratio, tied embeddings), tiny dims."""
    kv = max(1, cfg.n_kv_heads * 4 // cfg.n_heads)
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(n_experts=4, top_k=min(2, cfg.moe.top_k),
                        dense_residual=cfg.moe.dense_residual)
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64, n_heads=4, n_kv_heads=kv, head_dim=16,
        d_ff=96, vocab_size=128, moe=moe,
        sliding_window=(8 if cfg.sliding_window else None),
        remat="none", param_dtype="float32", compute_dtype="float32")


LM_ARCHS = [a for a in ALL_ARCHS if get_arch(a).family == "lm"]
GNN_ARCHS = [a for a in ALL_ARCHS if get_arch(a).family == "gnn"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(arch_id):
    cfg = reduced_lm(get_arch(arch_id).model)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    # train step value+grad
    loss, metrics = tfm.loss_fn(params, cfg, {"tokens": toks,
                                              "labels": toks})
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: tfm.loss_fn(p, cfg, {"tokens": toks,
                                                    "labels": toks})[0]
                     )(params)
    assert all(np.all(np.isfinite(g))
               for g in jax.tree_util.tree_leaves(grads))
    # prefill -> decode consistency of shapes
    logits, _, cache_pf = tfm.forward(params, cfg, toks, return_cache=True)
    assert logits.shape == (2, 16, tfm.padded_vocab(cfg))
    cache = tfm.init_cache(cfg, 2, 32)
    lg, cache = tfm.decode_step(params, cfg, cache, toks[:, 0])
    assert lg.shape == (2, tfm.padded_vocab(cfg))
    assert np.all(np.isfinite(np.asarray(lg)))
    assert int(cache["pos"][0]) == 1


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_smoke(arch_id, rng):
    full = get_arch(arch_id).model
    cfg = dataclasses.replace(full, n_layers=2, d_hidden=16,
                              l_max=min(full.l_max, 2),
                              m_max=min(full.m_max, 1),
                              n_heads=min(full.n_heads, 2) or 1)
    mod = GNN_MODULES[cfg.kind]
    n, e, d = 24, 72, 8
    g = {
        "x": jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32)),
        "senders": jnp.asarray(rng.randint(0, n, e).astype(np.int32)),
        "receivers": jnp.asarray(rng.randint(0, n, e).astype(np.int32)),
        "pos": jnp.asarray(rng.normal(0, 1, (n, 3)).astype(np.float32)),
    }
    params = mod.init(jax.random.PRNGKey(0), cfg, d, cfg.n_classes)
    out = mod.apply(params, cfg, g)
    assert out.shape == (n, cfg.n_classes)
    assert np.all(np.isfinite(np.asarray(out)))
    # one grad step through a scalar loss
    grads = jax.grad(
        lambda p: jnp.mean(jnp.square(mod.apply(p, cfg, g))))(params)
    assert all(np.all(np.isfinite(x))
               for x in jax.tree_util.tree_leaves(grads))


def test_fm_smoke(rng):
    full = get_arch("fm").model
    cfg = RecsysConfig(name="fm-small", n_sparse=6, embed_dim=4,
                       vocab_sizes=(50, 40, 30, 20, 10, 5))
    params = fm_lib.init(jax.random.PRNGKey(0), cfg)
    ids = jnp.asarray(np.stack([rng.randint(0, s, 16)
                                for s in cfg.vocab_sizes], 1))
    logits = fm_lib.forward(params, cfg, ids)
    assert logits.shape == (16,)
    assert np.all(np.isfinite(np.asarray(logits)))
    loss, m = fm_lib.loss_fn(params, cfg, {"ids": ids,
                                           "y": jnp.ones((16,))})
    assert np.isfinite(float(loss))
    # kernel path matches XLA path
    lk = fm_lib.forward(params, cfg, ids, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(lk),
                               rtol=1e-4, atol=1e-4)
    # retrieval
    sc = fm_lib.retrieval_score(params, cfg, ids[0, :-1], jnp.arange(5))
    assert sc.shape == (5,) and np.all(np.isfinite(np.asarray(sc)))


@pytest.mark.parametrize("arch_id", ["jedinet-30p", "jedinet-50p"])
def test_jedi_smoke(arch_id):
    from repro.core import interaction_net as inet
    cfg = dataclasses.replace(get_arch(arch_id).model,
                              fr_hidden=(8,), fo_hidden=(8,),
                              phi_hidden=(8,))
    params = inet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (4, cfg.n_objects, cfg.n_features))
    logits = inet.forward_sr(params, cfg, x)
    assert logits.shape == (4, cfg.n_targets)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_registry_covers_assignment():
    """All 10 assigned archs + 4 shapes each are registered (40 cells)."""
    from repro.configs.registry import ASSIGNED_ARCHS, iter_cells
    assert len(ASSIGNED_ARCHS) == 10
    total = list(iter_cells(include_skipped=True))
    assert len(total) == 40
    runnable = list(iter_cells())
    assert len(runnable) == 36       # 4 documented long_500k skips
