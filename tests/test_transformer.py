"""Transformer integration: decode-vs-prefill consistency, SWA window,
chunked cross-entropy, MoE arch training."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, TransformerConfig
from repro.models import transformer as tfm


BASE = TransformerConfig(
    name="t-test", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab_size=100, remat="none", compute_dtype="float32")


def _toks(b, s, v=100, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, v)


@pytest.mark.parametrize("cfg", [
    BASE,
    dataclasses.replace(BASE, sliding_window=8),
    dataclasses.replace(BASE, tie_embeddings=True),
    dataclasses.replace(BASE, moe=MoEConfig(n_experts=4, top_k=2,
                                            capacity_factor=8.0)),
], ids=["dense", "swa", "tied", "moe"])
def test_decode_matches_prefill(cfg):
    """Greedy decode over a cache reproduces teacher-forced logits."""
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    toks = _toks(2, 12, cfg.vocab_size)
    logits_tf, _ = tfm.forward(params, cfg, toks)

    cache = tfm.init_cache(cfg, 2, 16)
    outs = []
    for t in range(12):
        lg, cache = tfm.decode_step(params, cfg, cache, toks[:, t])
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_tf),
                               np.asarray(logits_dec),
                               rtol=2e-3, atol=2e-3)


def test_swa_rolling_cache_beyond_window():
    """Decode past the window with a rolling cache == full forward with
    SWA masking (positions beyond the window don't affect logits)."""
    cfg = dataclasses.replace(BASE, sliding_window=6)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    s = 20
    toks = _toks(1, s, cfg.vocab_size)
    logits_tf, _ = tfm.forward(params, cfg, toks)

    cache = tfm.init_cache(cfg, 1, s)         # rolling: len == window 6
    assert cache["k"].shape[2] == 6
    outs = []
    for t in range(s):
        lg, cache = tfm.decode_step(params, cfg, cache, toks[:, t])
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_tf),
                               np.asarray(logits_dec),
                               rtol=2e-3, atol=2e-3)


def test_chunked_cross_entropy_matches_full():
    cfg = BASE
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": _toks(2, 16), "labels": _toks(2, 16, seed=1)}
    full, _ = tfm.loss_fn(params, cfg, batch, logit_chunk=None)
    chunked, _ = tfm.loss_fn(params, cfg, batch, logit_chunk=4)
    assert abs(float(full) - float(chunked)) < 1e-4
    # grads match too
    g1 = jax.grad(lambda p: tfm.loss_fn(p, cfg, batch)[0])(params)
    g2 = jax.grad(lambda p: tfm.loss_fn(p, cfg, batch,
                                        logit_chunk=4)[0])(params)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)))
    assert err < 1e-4


def test_label_masking():
    cfg = BASE
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    toks = _toks(2, 8)
    labels = _toks(2, 8, seed=1)
    masked = labels.at[:, 4:].set(-1)
    l_all, _ = tfm.loss_fn(params, cfg, {"tokens": toks, "labels": labels})
    l_mask, m = tfm.loss_fn(params, cfg, {"tokens": toks, "labels": masked})
    assert float(l_all) != float(l_mask)
    assert np.isfinite(float(l_mask))


def test_blockwise_attention_in_forward():
    """kv_chunk smaller than seq produces identical logits."""
    cfg = BASE
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    toks = _toks(2, 32)
    a, _ = tfm.forward(params, cfg, toks, kv_chunk=2048)
    b, _ = tfm.forward(params, cfg, toks, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)
    c, _ = tfm.forward(params, cfg, toks, kv_chunk=8, q_chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               rtol=2e-3, atol=2e-3)


def test_remat_matches_no_remat():
    cfg_r = dataclasses.replace(BASE, remat="layer")
    params = tfm.init(jax.random.PRNGKey(0), cfg_r)
    batch = {"tokens": _toks(2, 8), "labels": _toks(2, 8, seed=1)}
    l1, _ = tfm.loss_fn(params, BASE, batch)
    l2, _ = tfm.loss_fn(params, cfg_r, batch)
    assert abs(float(l1) - float(l2)) < 1e-5
