"""Continuous-batching LM serving demo (the decode_32k cell's code path
at CPU scale).

    PYTHONPATH=src python examples/serve_lm.py

Runs the h2o-danube arch (reduced dims, same code) through the serving
driver: request queue -> prefill -> batched decode with slot recycling.
"""

from repro.launch import serve


def main():
    serve.main([
        "--arch", "h2o-danube-1.8b",
        "--tiny",
        "--requests", "8",
        "--slots", "4",
        "--prompt-len", "24",
        "--max-new", "12",
        "--max-seq", "64",
    ])


if __name__ == "__main__":
    main()
