"""End-to-end training driver: JEDI-net-30p on synthetic jets, a few
hundred steps with async checkpointing and restart.

    PYTHONPATH=src python examples/train_jedinet.py [--steps 300]

This is the paper's application trained end to end through the full
framework stack: data pipeline (prefetch thread) -> strength-reduced
forward -> AdamW + warmup-cosine -> async checkpoints. Accuracy on the
5-class synthetic surrogate rises well above the 20% chance level within
~200 steps.
"""

import argparse
import sys

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="/tmp/jedinet_ckpt")
    args = ap.parse_args()
    train_driver.main([
        "--arch", "jedinet-30p",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--lr", "2e-3",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--log-every", "25",
    ])


if __name__ == "__main__":
    main()
