"""Algorithm-hardware co-design DSE (paper Sec 4.4 / Fig 11), closed loop:

1. enumerate the (f_R, f_O, N_fR) space, prune by eq.(1) DSPs and
   eq.(2) latency (alpha x 1us budget) — no training needed for pruned
   points (the paper's GPU-hours saving);
2. pick Opt-Latn and Opt-Acc candidates by the capacity proxy;
3. THEN actually train both picks (plus the J1 baseline) briefly on the
   synthetic jet surrogate and report real accuracies, validating that
   the co-design trade (small f_R, big f_O) holds under training.

    PYTHONPATH=src python examples/codesign_search.py [--steps 150]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import codesign, interaction_net as inet
from repro.data.jets import jet_batches
from repro.training import init_state, make_optimizer, make_train_step
from repro.training.schedule import warmup_cosine


def train_and_eval(cfg, steps: int, batch: int = 256) -> float:
    opt = make_optimizer("adamw", warmup_cosine(2e-3, 20, steps))
    state = init_state(jax.random.PRNGKey(0),
                       lambda k: inet.init(k, cfg), opt)
    step = jax.jit(make_train_step(
        lambda p, b: inet.loss_fn(p, cfg, b), opt))
    it = jet_batches(0, batch, cfg.n_objects, cfg.n_features)
    for _ in range(steps):
        b = next(it)
        state, _ = step(state, {"x": jnp.asarray(b["x"]),
                                "y": jnp.asarray(b["y"])})
    # held-out eval
    ev = jet_batches(999, 2048, cfg.n_objects, cfg.n_features)
    b = next(ev)
    logits = inet.forward_sr(state["params"], cfg, jnp.asarray(b["x"]))
    acc = float(jnp.mean((jnp.argmax(logits, -1) ==
                          jnp.asarray(b["y"])).astype(jnp.float32)))
    return acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    base = inet.JediNetConfig(n_objects=30, n_features=16)
    res = codesign.explore(base, latency_budget_us=1.0, alpha=2.0)
    print(f"DSE: {res['n_total']} candidates, "
          f"{res['training_runs_saved']} pruned without training "
          f"({res['training_runs_saved']/res['n_total']*100:.0f}% of "
          "GPU-hours saved)")

    picks = {
        "J1-baseline": (base, None),
        "Opt-Latn": (res["opt_latn"].cfg, res["opt_latn"]),
        "Opt-Acc": (res["opt_acc"].cfg, res["opt_acc"]),
    }
    print(f"\n{'design':<12} {'f_R':<16} {'f_O':<16} "
          f"{'latency_us':<11} {'trained acc'}")
    for name, (cfg, cand) in picks.items():
        lat = (cand.fpga["latency_us"] if cand else
               codesign.FPGAModel.evaluate(
                   codesign.FPGADesignPoint(cfg=cfg, n_fr=1))["latency_us"])
        acc = train_and_eval(cfg, args.steps)
        print(f"{name:<12} {str(cfg.fr_hidden):<16} "
              f"{str(cfg.fo_hidden):<16} {lat:<11.2f} {acc*100:.1f}%")
    print("\nThe co-design claim: Opt-Latn shrinks f_R (many-iteration "
          "unit) >10x in latency at small accuracy cost; Opt-Acc buys "
          "accuracy back with a bigger f_O within the 1us budget.")


if __name__ == "__main__":
    main()
