"""Quickstart: the paper's technique in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Build JEDI-net-30p and enumerate the forward-path registry
   (`repro.core.paths`) — every optimization tier of the paper is one
   registered `PathSpec`, from the dense-MMM baseline of [5] to the
   int8-quantized whole-network kernel.
2. Run each registered path against its own declared reference fn
   (Pallas kernels in interpret mode on CPU) at its declared tolerance.
3. Print the Fig-8 op-count reduction and a wall-clock comparison.
"""

import time

import jax
import jax.numpy as jnp

from repro.core import adjacency, interaction_net as inet, paths


def main():
    cfg = inet.JediNetConfig(n_objects=30, n_features=16)
    params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 30, 16))

    print("registered forward paths:\n" + paths.describe() + "\n")

    # every path vs its own spec-declared reference (small batch: the
    # Pallas kernels run in interpret mode on CPU)
    xs = x[:8]
    for name in paths.available():
        spec = paths.get(name)
        p = spec.prepare_params(params)
        out = (spec.forward(p, cfg, xs, interpret=True) if spec.pallas
               else spec.forward(p, cfg, xs))
        err = float(jnp.max(jnp.abs(out - spec.ref(p, cfg, xs))))
        ok = "ok" if err < spec.tolerance else "FAIL"
        print(f"{name:>16} vs ref: max err {err:.2e} "
              f"(tol {spec.tolerance:.0e}) {ok}")

    c = adjacency.mmm_op_counts(30, 16, 8)
    print(f"\nFig 8 (30p): MMM1/2 mults {c['mmm12_baseline_mults']:,} -> 0, "
          f"MMM3 adds {c['mmm3_baseline_adds']:,} -> {c['mmm3_sr_adds']:,} "
          f"({c['mmm3_sr_adds']/c['mmm3_baseline_adds']*100:.1f}%), "
          f"iterations {c['iterations_baseline']} -> {c['iterations_sr']}")

    # wall-clock for the XLA paths (kernel paths are TPU-targeted;
    # interpret-mode timing on CPU says nothing)
    print()
    for name in paths.available(pallas=False):
        spec = paths.get(name)
        pparams = spec.prepare_params(params)
        f = jax.jit(lambda p, a, s=spec: s.forward(p, cfg, a))
        f(pparams, x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            f(pparams, x).block_until_ready()
        print(f"{name:>16}: {(time.perf_counter()-t0)/10*1e3:.2f} ms / "
              "256-jet batch (CPU)")

    # 4. the large-graph regime: N_o=128 track-level events fit ONLY
    # through the sender-tiled kernel — the untiled working-set model
    # rejects even a single sample's (N_o, N_o, H1) grid.
    from repro.configs.jedi_tracks_128 import MODEL as tcfg
    from repro.data.jets import make_tracks
    from repro.kernels.fused_jedinet import autotune as fj_autotune
    import numpy as np
    tparams = inet.init(jax.random.PRNGKey(0), tcfg, scale="lecun")
    widths = tuple(fj_autotune.mlp_widths(tparams[k])
                   for k in ("fr", "fo", "phi"))
    untiled = fj_autotune.full_forward_bytes_per_sample(
        tcfg.n_objects, tcfg.n_features, *widths)
    # same reservation the forward call's internal autotune applies, so
    # the printed tile is the tile that actually runs
    bb, bs = fj_autotune.pick_block_b_s(
        4, tcfg.n_objects, tcfg.n_features, *widths,
        reserved_bytes=fj_autotune.weight_vmem_bytes(tparams,
                                                     tcfg.compute_dtype))
    xt = jnp.asarray(make_tracks(np.random.RandomState(0), 4)[0])
    spec = paths.get("fused_full")
    logits = spec.forward(tparams, tcfg, xt, interpret=True)
    err = float(jnp.max(jnp.abs(logits - spec.ref(tparams, tcfg, xt))))
    print(f"\ntracks128 (N_o={tcfg.n_objects}): untiled model needs "
          f"{untiled / 2**20:.2f} MiB/sample (> budget, rejected); "
          f"tiled kernel runs block_b={bb} block_s={bs}, "
          f"err vs ref {err:.1e}")


if __name__ == "__main__":
    main()
