"""Quickstart: the paper's technique in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Build JEDI-net-30p, run the dense-MMM baseline of [5].
2. Run the strength-reduced path (paper Sec 3.1-3.3) — same numbers,
   no adjacency matrices, no MMM FLOPs.
3. Run the fused Pallas kernel (paper Sec 3.5, interpret mode on CPU).
4. Print the Fig-8 op-count reduction and a wall-clock comparison.
"""

import time

import jax
import jax.numpy as jnp

from repro.core import adjacency, interaction_net as inet


def main():
    cfg = inet.JediNetConfig(n_objects=30, n_features=16)
    params = inet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 30, 16))

    dense = jax.jit(lambda p, a: inet.forward_dense(p, cfg, a))
    sr = jax.jit(lambda p, a: inet.forward_sr(p, cfg, a))

    out_d = dense(params, x)
    out_s = sr(params, x)
    err = float(jnp.max(jnp.abs(out_d - out_s)))
    print(f"strength-reduced == dense baseline: max err {err:.2e}")

    out_f = inet.forward_fused(params, cfg, x, interpret=True)
    err_f = float(jnp.max(jnp.abs(out_s - out_f)))
    print(f"fused Pallas kernel == strength-reduced: max err {err_f:.2e}")

    c = adjacency.mmm_op_counts(30, 16, 8)
    print(f"\nFig 8 (30p): MMM1/2 mults {c['mmm12_baseline_mults']:,} -> 0, "
          f"MMM3 adds {c['mmm3_baseline_adds']:,} -> {c['mmm3_sr_adds']:,} "
          f"({c['mmm3_sr_adds']/c['mmm3_baseline_adds']*100:.1f}%), "
          f"iterations {c['iterations_baseline']} -> {c['iterations_sr']}")

    for name, f in (("dense", dense), ("strength-reduced", sr)):
        f(params, x)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            f(params, x).block_until_ready()
        print(f"{name:>17}: {(time.perf_counter()-t0)/10*1e3:.2f} ms / "
              "256-jet batch (CPU)")


if __name__ == "__main__":
    main()
