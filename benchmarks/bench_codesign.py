"""Paper Fig 11/12 + Sec 5.4.4: algorithm-hardware co-design DSE.

Reproduces the search over (f_R NL/size, f_O first-layer size, N_fR,
R_fO) with the eq.(1)/(2) pruning, and reports Opt-Latn / Opt-Acc picks
plus the training-runs-saved count (the paper's GPU-hours argument).
"""

from __future__ import annotations

from repro.core import codesign
from repro.core.interaction_net import JediNetConfig
from benchmarks.common import row


def run():
    rows = []
    for name, n_o, alpha, fr_sizes in (
            ("30p", 30, 2.0, (8, 16, 24, 32)),
            ("50p", 50, 4.0, (8, 16, 32, 48))):
        base = JediNetConfig(n_objects=n_o, n_features=16)
        res = codesign.explore(base, latency_budget_us=1.0, alpha=alpha,
                               fr_size=fr_sizes)
        ol, oa = res["opt_latn"], res["opt_acc"]
        rows.append(row(
            f"fig11_explored_{name}", 0.0,
            f"{res['n_total']} candidates; pruned {res['n_pruned_dsp']} "
            f"DSP + {res['n_pruned_latency']} latency = "
            f"{res['training_runs_saved']} training runs saved "
            f"({res['training_runs_saved']/res['n_total']*100:.0f}%)"))
        rows.append(row(
            f"fig11_opt_latn_{name}", ol.fpga["latency_us"],
            f"fR={ol.cfg.fr_hidden} fO={ol.cfg.fo_hidden} N_fR={ol.n_fr} "
            f"II={ol.fpga['ii_us']:.2f}us proxy-acc={ol.accuracy:.1f} "
            f"(paper {'J4' if n_o == 30 else 'U4'}: "
            f"{0.29 if n_o == 30 else 0.65}us)"))
        rows.append(row(
            f"fig11_opt_acc_{name}", oa.fpga["latency_us"],
            f"fR={oa.cfg.fr_hidden} fO={oa.cfg.fo_hidden} N_fR={oa.n_fr} "
            f"proxy-acc={oa.accuracy:.1f} (paper "
            f"{'J5' if n_o == 30 else 'U5'}: 0.91us)"))
        # the paper's qualitative claim: Opt-Latn shrinks f_R, not f_O
        assert ol.cfg.fr_hidden[0] <= base.fr_hidden[0]
        assert ol.fpga["latency_us"] <= 1.0
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
