"""Serving-tier trajectory benchmark: KGPS + per-event p50/p99 per bucket.

Pumps a short synthetic stream through the :class:`ServingEngine` for
each (config x forward path x ladder bucket) and records sustained KGPS
plus per-event p50/p99 next to the TPU-model roofline for that bucket.
``run()`` fills ``JSON_PAYLOAD``; ``benchmarks/run.py`` writes it to
``BENCH_serving.json`` (``JSON_NAME``) so the serving trajectory is
machine-trackable across PRs and gated by ``check_regression.py``.

Pallas paths run in interpret mode off-TPU: their wall-clock is a CPU
emulation (flagged ``"interpret": true`` in the JSON) — the roofline is
the cross-PR comparable number there, exactly as in bench_fused_full.
Bucket counts/stream lengths are kept small off-TPU so CI stays fast.

Serving rides the fault-tolerant :class:`ResilientEngine` — the same
layer production traffic goes through — so the committed numbers
include the degradation ladder's (fault-free) overhead: the stream hot
loop still delegates to the sub-engine's double-buffered feed, so the
cost is one try/except + health bookkeeping per stream, <5% by
construction (verified at the PR that introduced it; see EXPERIMENTS.md
§Fault drills).

The stream benchmark additionally arms the silent-corruption sentinel
(golden canaries + post-hoc shadow verification of a duty-cycled tick
sample — the hot loop itself stays untouched, verification runs after
the stream returns and is excluded from the gated wall-clocks).  The
sentinel's amortized cost is measured in a dedicated per-path window
at production cadence (``canary_every=128``, ``shadow_rate=1/512``)
over a long stream, reported as ``sentinel["overhead"]`` — the ≤5%
budget EXPERIMENTS.md §Sentinel tracks.  The short gated per-bucket
streams are NOT the place to read that ratio: 8 ticks cannot amortize
a 1/128-cadence canary.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row, select_paths
from repro.core import interaction_net as inet
from repro.serving import ResilientEngine, SentinelConfig, ServingLoop

JSON_NAME = "BENCH_serving.json"
JSON_PAYLOAD: dict = {}

# default subset: the XLA production fallback, the whole-network kernel
# and its O(N) JEDI-linear rival — the head-to-head the serving tier
# tracks across PRs (off-TPU interpret emulation is slow;
# `benchmarks.run --paths all` widens this to every registered path,
# e.g. for a TPU baseline run)
PATHS = ("sr_split", "fused_full", "jedi_linear_full")


def _bench_engine(cfg, params, path, *, on_tpu):
    # sentinel armed exactly as production would run it: sync shadows
    # (the verification is post-hoc anyway) at a 1/16 duty cycle
    engine = ResilientEngine(params, cfg, forward=path,
                             max_batch=1024 if on_tpu else 64,
                             sentinel=SentinelConfig(shadow_rate=1 / 16,
                                                     shadow_sync=True))
    interpret = engine.interpret
    # off-TPU interpret emulation is slow — trim buckets and stream length
    buckets = engine.bucket_sizes if on_tpu else engine.bucket_sizes[:3]
    n_batches = 8
    warmup = 2
    roofline = engine.roofline(buckets)

    out = {}
    rng = np.random.RandomState(0)
    for bucket in buckets:
        # non-aligned tick size: exercises the pad-to-bucket path
        n_valid = max(1, bucket - 3)
        stream = [rng.normal(0, 1, (n_valid, cfg.n_objects, cfg.n_features))
                  .astype(np.float32) for _ in range(n_batches + warmup)]
        res = engine.run_stream(stream, warmup=warmup)
        snap = engine.metrics.snapshot()
        min_us = min(res["latencies"]) * 1e6
        out[str(bucket)] = {
            "kgps": res["kgps"],
            "p50_us": snap["p50_us"],
            "p99_us": snap["p99_us"],
            "per_event_p50_us": snap["p50_us"] / n_valid,
            "per_event_p99_us": snap["p99_us"] / n_valid,
            # min is the noise-robust estimator the regression gate uses
            # (percentiles on a short CPU stream jump with scheduler pauses)
            "min_us": min_us,
            "per_event_min_us": min_us / n_valid,
            "n_valid": n_valid,
            "batches": len(res["latencies"]),
            "modeled_step_us": roofline[bucket]["step_us"],
            "modeled_bound": roofline[bucket]["bound"],
        }
        # fresh window per bucket so percentiles don't mix shapes
        engine.metrics = type(engine.metrics)()
    sentinel = _sentinel_window(engine, cfg, rng)
    return {"interpret": interpret, "sentinel": sentinel, "buckets": out}


def _sentinel_window(engine, cfg, rng, *, ticks: int = 512):
    """Measure the sentinel's amortized verification cost at production
    cadence: a long stream on the smallest bucket so the 1/128 canary
    cadence and the 1/512 shadow duty cycle both fire at their real
    rates (the 8-tick gated streams above would charge a whole canary
    to 8 batches).  Overhead = post-hoc verification wall / stream
    wall; the hot loop itself is untouched either way."""
    prod = SentinelConfig(canary_every=128, shadow_rate=1 / 512,
                          shadow_sync=True)
    sent, old = engine.sentinel, engine.sentinel.config
    bucket = engine.bucket_sizes[0]
    n_valid = max(1, bucket - 3)
    stream = [rng.normal(0, 1, (n_valid, cfg.n_objects, cfg.n_features))
              .astype(np.float32) for _ in range(ticks + 2)]
    try:
        # warm the shadow oracle OUTSIDE the window: the terminal rung's
        # construction + first compile is a startup cost (production
        # warms it at boot), not part of the duty cycle being measured
        sent.config = SentinelConfig(canary_every=10**9, shadow_rate=1.0,
                                     shadow_sync=True)
        engine.run_stream(stream[:3], warmup=1)
        sent.config = prod
        engine.metrics = type(engine.metrics)()
        res = engine.run_stream(stream, warmup=2)
    finally:
        sent.config = old
    verify_s = engine.metrics.gauge_value("sentinel_verify_s")
    return {
        "ticks": ticks,
        "bucket": bucket,
        "canary_every": prod.canary_every,
        "shadow_rate": prod.shadow_rate,
        "canaries": engine.metrics.counter("canaries"),
        "shadows": engine.metrics.counter("shadow_requests"),
        "verify_s": verify_s,
        "stream_wall_s": res["wall_s"],
        "overhead": (verify_s / res["wall_s"]
                     if res["wall_s"] > 0 else float("nan")),
    }


def _bench_queue(cfg, params, path, *, on_tpu):
    """Queue-driven serving: Poisson arrivals through the event loop.

    The offline stream above measures the feed loop at saturation; this
    measures the LIVE front-end — individual requests arriving at ~80%
    of measured capacity, cut by the :class:`DeadlineBatcher` fuse,
    dispatched with bounded in-flight backpressure — and reports the
    sustained KGPS the loop actually delivered plus the shed rate.
    One bucket entry keyed by the ladder top, gate-compatible with the
    per-bucket stream entries (``per_event_min_us`` present).
    """
    engine = ResilientEngine(params, cfg, forward=path,
                             max_batch=256 if on_tpu else 16)
    interpret = engine.interpret
    top = engine.bucket_sizes[-1]
    rng = np.random.RandomState(1)

    # calibrate capacity on a warm top-bucket batch; the arrival rate is
    # set relative to it so the benchmark loads the loop the same way on
    # any machine (absolute rates would saturate CPU and idle TPU)
    x_cal = rng.normal(0, 1, (top, cfg.n_objects, cfg.n_features)) \
        .astype(np.float32)
    engine.infer(x_cal)                                  # compile
    cal_lat = min(_timed(engine, x_cal) for _ in range(3))
    capacity_eps = top / cal_lat
    rate_eps = 0.8 * capacity_eps

    engine.metrics = type(engine.metrics)()              # drop calibration
    loop = ServingLoop(engine, deadline_s=max(1e-3, cal_lat),
                       max_inflight=4)
    n_req = 200 if on_tpu else 24
    sizes = 1 + rng.poisson(3.0, n_req)                  # mean ~4 events
    gaps = rng.exponential(float(sizes.mean()) / rate_eps, n_req)
    xs = [rng.normal(0, 1, (int(s), cfg.n_objects, cfg.n_features))
          .astype(np.float32) for s in sizes]
    deadline_s = 50 * cal_lat                            # generous serve-by

    futs = []
    t0 = time.perf_counter()
    t_next = t0
    for x, gap in zip(xs, gaps):
        t_next += gap
        while time.perf_counter() < t_next:
            loop.poll()                  # service the fuse between arrivals
        futs.append(loop.submit(x, deadline_s=deadline_s))
    loop.drain()
    wall = time.perf_counter() - t0

    served = sum(f.n_events for f in futs if not f.shed)
    shed = sum(f.n_events for f in futs if f.shed)
    snap = engine.metrics.snapshot()
    recs = list(engine.metrics._records)
    per_event_min_us = (min(r.latency_s / r.events for r in recs
                            if r.events) * 1e6 if recs else float("nan"))
    return {"interpret": interpret, "buckets": {str(top): {
        "kgps": served / wall / 1e3 if wall > 0 else float("nan"),
        "shed_rate": shed / max(served + shed, 1),
        "p50_us": snap["p50_us"],
        "p99_us": snap["p99_us"],
        "per_event_p50_us": snap["per_event_p50_us"],
        "per_event_p99_us": snap["per_event_p99_us"],
        "per_event_min_us": per_event_min_us,
        "queue_depth_max": engine.metrics.gauge_max("queue_depth"),
        "inflight_max": engine.metrics.gauge_max("inflight_plans"),
        "requests": n_req,
        "rate_eps": rate_eps,
        "batches": snap["batches"],
    }}}


def _timed(engine, x) -> float:
    t0 = time.perf_counter()
    engine.infer(x)
    return time.perf_counter() - t0


def run():
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    payload = {"schema": 1, "backend": jax.default_backend(), "configs": {}}

    for cname, n_o in (("30p", 30), ("50p", 50)):
        cfg = inet.JediNetConfig(n_objects=n_o, n_features=16)
        params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
        entry = {"n_objects": n_o, "paths": {}}
        for path in select_paths(default=PATHS):
            res = _bench_engine(cfg, params, path, on_tpu=on_tpu)
            entry["paths"][path] = res
            for bucket, b in res["buckets"].items():
                rows.append(row(
                    f"serving_{cname}_{path}_b{bucket}",
                    b["p50_us"],
                    f"kgps={b['kgps']:.1f} per_event_p50={b['per_event_p50_us']:.2f}us"
                    f" modeled={b['modeled_step_us']:.1f}us"
                    f"{' (interpret)' if res['interpret'] else ''}"))
            qres = _bench_queue(cfg, params, path, on_tpu=on_tpu)
            entry["paths"][f"queue_{path}"] = qres
            for bucket, b in qres["buckets"].items():
                rows.append(row(
                    f"serving_{cname}_queue_{path}_b{bucket}",
                    b["p50_us"],
                    f"kgps={b['kgps']:.1f} shed={b['shed_rate']:.0%} "
                    f"qmax={b['queue_depth_max']:.0f}"
                    f"{' (interpret)' if qres['interpret'] else ''}"))
        payload["configs"][cname] = entry

    JSON_PAYLOAD.clear()
    JSON_PAYLOAD.update(payload)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
