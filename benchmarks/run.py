"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8,serving,...] \
        [--paths all|name,name,...]

``--paths`` steers the path-parametrized benchmarks (``fused_paths``,
``serving``): ``all`` enumerates every path in the forward-path
registry (:mod:`repro.core.paths`) — a newly registered path appears
in the emitted BENCH_*.json with no benchmark edits — while an
explicit comma list pins the set.  Default: each module's own subset.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).
Any benchmark module may define ``JSON_PAYLOAD`` (filled by its
``run()``) plus ``JSON_NAME``: the payload is then written to
``<out-dir>/<JSON_NAME>`` so the perf trajectory is machine-trackable
across PRs — ``fused_paths`` emits ``BENCH_fused.json``, ``serving``
emits ``BENCH_serving.json``.  The committed copies at the repo root
are the regression baselines (``benchmarks/check_regression.py``); CI
writes fresh copies to a scratch ``--out-dir`` and compares.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

from benchmarks import common
from benchmarks.common import calibration_us, print_rows

BENCHES = {
    "fig8_ops_reduction": "benchmarks.bench_ops_reduction",
    "table2_latency_model": "benchmarks.bench_latency_model",
    "fig9_sr_speedup": "benchmarks.bench_sr_speedup",
    "fig10_fusion": "benchmarks.bench_fusion",
    "fig11_codesign": "benchmarks.bench_codesign",
    "table3_throughput": "benchmarks.bench_throughput",
    "roofline_summary": "benchmarks.bench_roofline_summary",
    "fused_paths": "benchmarks.bench_fused_full",
    "serving": "benchmarks.bench_serving",
}

# legacy name kept so `--json-out` keeps steering the fused payload
_FUSED_JSON = "BENCH_fused.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark keys")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_*.json payloads")
    ap.add_argument("--json-out", default=None,
                    help=f"override path for {_FUSED_JSON} (legacy)")
    ap.add_argument("--paths", default=None,
                    help="forward paths for path-parametrized benchmarks: "
                         "'all' (whole registry) or comma-separated names")
    args = ap.parse_args()
    keys = args.only.split(",") if args.only else list(BENCHES)
    if args.paths:
        common.PATH_FILTER = args.paths.split(",")

    import importlib
    all_rows = []
    failed = []
    payloads: dict[str, dict] = {}   # out-path -> payload
    for k in keys:
        try:
            mod = importlib.import_module(BENCHES[k])
            all_rows.extend(mod.run())
            if getattr(mod, "JSON_PAYLOAD", None):
                name = getattr(mod, "JSON_NAME", _FUSED_JSON)
                path = os.path.join(args.out_dir, name)
                if args.json_out and name == _FUSED_JSON:
                    path = args.json_out
                payloads[path] = dict(mod.JSON_PAYLOAD)
        except Exception as e:  # noqa: BLE001
            failed.append(k)
            traceback.print_exc()
            all_rows.append({"name": f"{k}_FAILED", "us_per_call": 0.0,
                             "derived": str(e)})
    print_rows(all_rows)
    if payloads and args.out_dir != ".":
        os.makedirs(args.out_dir, exist_ok=True)
    if payloads:
        # one machine-speed yardstick per emission, shared by all payloads
        # (check_regression normalizes wall-clocks by the fresh/baseline
        # calibration ratio to cancel runner-speed differences)
        cal = calibration_us()
        for payload in payloads.values():
            payload["calibration_us"] = cal
    for path, payload in payloads.items():
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"\nwrote {path}", file=sys.stderr)
    if failed:
        print(f"\nFAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
