"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8,table2,...]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).
When the ``fused_paths`` benchmark runs, its per-path wall-clock +
modeled-HBM payload is also written to ``BENCH_fused.json`` (override
with ``--json-out``) so the perf trajectory is machine-trackable
across PRs.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks.common import print_rows

BENCHES = {
    "fig8_ops_reduction": "benchmarks.bench_ops_reduction",
    "table2_latency_model": "benchmarks.bench_latency_model",
    "fig9_sr_speedup": "benchmarks.bench_sr_speedup",
    "fig10_fusion": "benchmarks.bench_fusion",
    "fig11_codesign": "benchmarks.bench_codesign",
    "table3_throughput": "benchmarks.bench_throughput",
    "roofline_summary": "benchmarks.bench_roofline_summary",
    "fused_paths": "benchmarks.bench_fused_full",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark keys")
    ap.add_argument("--json-out", default="BENCH_fused.json",
                    help="where to write the fused_paths JSON payload")
    args = ap.parse_args()
    keys = args.only.split(",") if args.only else list(BENCHES)

    import importlib
    all_rows = []
    failed = []
    json_payload = None
    for k in keys:
        try:
            mod = importlib.import_module(BENCHES[k])
            all_rows.extend(mod.run())
            if k == "fused_paths":
                json_payload = dict(mod.JSON_PAYLOAD)
        except Exception as e:  # noqa: BLE001
            failed.append(k)
            traceback.print_exc()
            all_rows.append({"name": f"{k}_FAILED", "us_per_call": 0.0,
                             "derived": str(e)})
    print_rows(all_rows)
    if json_payload is not None:
        with open(args.json_out, "w") as f:
            json.dump(json_payload, f, indent=2, sort_keys=True)
        print(f"\nwrote {args.json_out}", file=sys.stderr)
    if failed:
        print(f"\nFAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
