"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8,table2,...]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import print_rows

BENCHES = {
    "fig8_ops_reduction": "benchmarks.bench_ops_reduction",
    "table2_latency_model": "benchmarks.bench_latency_model",
    "fig9_sr_speedup": "benchmarks.bench_sr_speedup",
    "fig10_fusion": "benchmarks.bench_fusion",
    "fig11_codesign": "benchmarks.bench_codesign",
    "table3_throughput": "benchmarks.bench_throughput",
    "roofline_summary": "benchmarks.bench_roofline_summary",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark keys")
    args = ap.parse_args()
    keys = args.only.split(",") if args.only else list(BENCHES)

    import importlib
    all_rows = []
    failed = []
    for k in keys:
        try:
            mod = importlib.import_module(BENCHES[k])
            all_rows.extend(mod.run())
        except Exception as e:  # noqa: BLE001
            failed.append(k)
            traceback.print_exc()
            all_rows.append({"name": f"{k}_FAILED", "us_per_call": 0.0,
                             "derived": str(e)})
    print_rows(all_rows)
    if failed:
        print(f"\nFAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
