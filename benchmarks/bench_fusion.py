"""Paper Fig 10 / Sec 5.4.3: the fusion step.

On the FPGA, fusion removes coarse-grained pipeline buffers (J2->J3:
1.91us -> 0.62us).  On TPU the analogue is HBM-traffic removal: the fused
kernel keeps B (N_E x 2P) and E (N_E x D_e) in VMEM.  We report (a) the
analytic HBM bytes saved per batch from the TPUModel, and (b) measured
interpret-mode equivalence cost on CPU (the kernel itself targets TPU, so
wall-clock here is NOT the claim — the traffic model is).
"""

from __future__ import annotations

import jax

from repro.core import codesign, interaction_net as inet
from benchmarks.common import row


def run():
    rows = []
    for name, n_o in (("30p", 30), ("50p", 50)):
        cfg = inet.JediNetConfig(n_objects=n_o, n_features=16)
        pt = codesign.TPUDesignPoint(cfg=cfg, batch=1024)
        unfused = codesign.TPUModel.evaluate(pt, "none")
        fused = codesign.TPUModel.evaluate(pt, "edge")
        full = codesign.TPUModel.evaluate(pt, "full")
        saved = unfused["hbm_bytes"] - fused["hbm_bytes"]
        rows.append(row(
            f"fig10_fusion_hbm_{name}", fused["step_us"],
            f"HBM {unfused['hbm_bytes']/1e6:.1f}MB->"
            f"{fused['hbm_bytes']/1e6:.1f}MB per 1024-batch "
            f"({saved / unfused['hbm_bytes'] * 100:.0f}% saved); "
            f"step {unfused['step_us']:.1f}->{fused['step_us']:.1f}us "
            f"({unfused['step_us']/fused['step_us']:.2f}x; paper J2->J3: "
            f"3.1x)"))
        rows.append(row(
            f"fig10_fusion_full_{name}", full["step_us"],
            f"whole-network kernel: HBM {fused['hbm_bytes']/1e6:.2f}MB->"
            f"{full['hbm_bytes']/1e6:.2f}MB per 1024-batch; "
            f"step {fused['step_us']:.2f}->{full['step_us']:.2f}us"))
        rows.append(row(
            f"fig10_bound_{name}", 0.0,
            f"bound none={unfused['bound']}, edge={fused['bound']}, "
            f"full={full['bound']}; arithmetic intensity "
            f"{unfused['arithmetic_intensity']:.0f}->"
            f"{fused['arithmetic_intensity']:.0f}->"
            f"{full['arithmetic_intensity']:.0f} flops/byte"))
    # sanity: fused paths == sr path numerically (interpret mode)
    cfg = inet.JediNetConfig(n_objects=30, n_features=16)
    params = inet.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 30, 16))
    sr = inet.forward_sr(params, cfg, x)
    fz = inet.forward_fused(params, cfg, x, interpret=True)
    err = float(jax.numpy.max(jax.numpy.abs(sr - fz)))
    rows.append(row("fig10_fused_allclose", 0.0, f"max_err {err:.1e}"))
    ff = inet.forward_fused_full(params, cfg, x[:16], interpret=True)
    err_full = float(jax.numpy.max(jax.numpy.abs(sr[:16] - ff)))
    rows.append(row("fig10_fused_full_allclose", 0.0,
                    f"max_err {err_full:.1e}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
