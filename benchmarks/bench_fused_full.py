"""Fused-path trajectory benchmark: per-path wall-clock + modeled HBM bytes.

Measures every FORWARD_FNS path on the paper's 30p / 50p configs and pairs
each wall-clock with the TPUModel's modeled HBM traffic at its fusion
level ("none" for the XLA paths, "edge" for the edge-only kernel, "full"
for the whole-network kernel).  ``run()`` also fills a machine-readable
payload that ``benchmarks/run.py`` writes to ``BENCH_fused.json`` so the
perf trajectory is tracked across PRs.

Pallas paths run in interpret mode off-TPU: their wall-clock is a CPU
emulation (flagged ``"interpret": true`` in the JSON) — the HBM model is
the cross-PR comparable number there, exactly as in bench_fusion.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import codesign, interaction_net as inet

# forward-path name -> TPUModel fusion level (single source of truth in
# core.codesign; the serving engine uses the same mapping)
PATH_LEVELS = codesign.PATH_FUSED_LEVELS

_INTERPRET_PATHS = ("fused", "fused_full")

# filled by run(); benchmarks/run.py serializes it to BENCH_fused.json
JSON_PAYLOAD: dict = {}


def _measure(name, params, cfg, x, interpret: bool):
    if name in _INTERPRET_PATHS:
        call = jax.jit(lambda p, x_: inet.FORWARD_FNS[name](
            p, cfg, x_, interpret=interpret))
    else:
        call = jax.jit(lambda p, x_: inet.FORWARD_FNS[name](p, cfg, x_))
    iters = 3 if interpret else 10
    us = time_fn(call, params, x, warmup=1, iters=iters)
    return us


def run():
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    payload = {"schema": 1, "backend": jax.default_backend(), "configs": {}}

    for cname, n_o, batch, ibatch in (("30p", 30, 256, 16),
                                      ("50p", 50, 128, 8)):
        cfg = inet.JediNetConfig(n_objects=n_o, n_features=16)
        params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
        entry = {"n_objects": n_o, "paths": {}}

        for name, level in PATH_LEVELS.items():
            interpret = (name in _INTERPRET_PATHS) and not on_tpu
            b = ibatch if interpret else batch
            x = jax.random.normal(jax.random.PRNGKey(1), (b, n_o, 16))
            us = _measure(name, params, cfg, x, interpret)
            hbm = codesign.TPUModel.hbm_bytes(cfg, batch, 2, fused=level)
            entry["paths"][name] = {
                "wall_us": us,
                "batch": b,
                "interpret": interpret,
                "fused_level": level,
                "modeled_hbm_bytes": hbm,
                "modeled_hbm_batch": batch,
            }
            rows.append(row(
                f"fused_paths_{cname}_{name}", us,
                f"level={level} modeled_hbm={hbm / 1e6:.2f}MB"
                f"{' (interpret)' if interpret else ''}"))

        # equivalence check rides along so the JSON records correctness too
        xq = jax.random.normal(jax.random.PRNGKey(2), (8, n_o, 16))
        sr = inet.forward_sr(params, cfg, xq)
        full = inet.forward_fused_full(params, cfg, xq,
                                       interpret=not on_tpu)
        err = float(jnp.max(jnp.abs(sr - full)))
        entry["fused_full_max_abs_err_vs_sr"] = err
        rows.append(row(f"fused_paths_{cname}_allclose", 0.0,
                        f"max_err {err:.1e}"))
        payload["configs"][cname] = entry

    JSON_PAYLOAD.clear()
    JSON_PAYLOAD.update(payload)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
