"""Fused-path trajectory benchmark: per-path wall-clock + modeled HBM bytes.

Measures every registered forward path (:mod:`repro.core.paths`) on the
paper's 30p / 50p configs and pairs each wall-clock with the TPUModel's
modeled HBM traffic at the path's declared fusion level and weight
precision — both read off the :class:`~repro.core.paths.PathSpec`, so a
newly registered path (e.g. the int8 quantized one) lands in this
benchmark, the emitted ``BENCH_fused.json`` and the CI regression gate
with zero edits here.  Each path's numerical error against its own
spec-declared reference fn rides along in the payload so the JSON
records correctness next to speed.

Pallas paths run in interpret mode off-TPU: their wall-clock is a CPU
emulation (flagged ``"interpret": true`` in the JSON) — the HBM model is
the cross-PR comparable number there, exactly as in bench_fusion.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, select_paths, time_fn
from repro.core import codesign, paths
from repro.core import interaction_net as inet

# filled by run(); benchmarks/run.py serializes it to BENCH_fused.json
JSON_PAYLOAD: dict = {}


def _measure(spec, params, cfg, x, interpret: bool):
    if spec.pallas:
        call = jax.jit(lambda p, x_: spec.forward(p, cfg, x_,
                                                  interpret=interpret))
    else:
        call = jax.jit(lambda p, x_: spec.forward(p, cfg, x_))
    iters = 3 if interpret else 10
    return time_fn(call, params, x, warmup=1, iters=iters)


def run():
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    payload = {"schema": 1, "backend": jax.default_backend(), "configs": {}}
    names = select_paths()                 # default: the whole registry

    for cname, n_o, batch, ibatch in (("30p", 30, 256, 16),
                                      ("50p", 50, 128, 8)):
        cfg = inet.JediNetConfig(n_objects=n_o, n_features=16)
        params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
        entry = {"n_objects": n_o, "paths": {}}

        for name in names:
            spec = paths.get(name)
            pparams = spec.prepare_params(params)
            interpret = spec.pallas and not on_tpu
            b = ibatch if interpret else batch
            x = jax.random.normal(jax.random.PRNGKey(1), (b, n_o, 16))
            us = _measure(spec, pparams, cfg, x, interpret)
            hbm = codesign.TPUModel.hbm_bytes(
                cfg, batch, 2, spec.fused_level,
                weight_bytes=spec.weight_bytes)
            # path-vs-own-reference error rides along (the spec contract:
            # both fns see the transformed params)
            xq = jax.random.normal(jax.random.PRNGKey(2), (8, n_o, 16))
            fwd = (spec.forward(pparams, cfg, xq, interpret=True)
                   if spec.pallas and not on_tpu
                   else spec.forward(pparams, cfg, xq))
            err = float(jnp.max(jnp.abs(fwd - spec.ref(pparams, cfg, xq))))
            entry["paths"][name] = {
                "wall_us": us,
                "batch": b,
                "interpret": interpret,
                "fused_level": spec.fused_level,
                "quantized": spec.quantized,
                "modeled_hbm_bytes": hbm,
                "modeled_hbm_batch": batch,
                "max_abs_err_vs_ref": err,
                "ref_tolerance": spec.tolerance,
            }
            rows.append(row(
                f"fused_paths_{cname}_{name}", us,
                f"level={spec.fused_level} modeled_hbm={hbm / 1e6:.2f}MB "
                f"err={err:.1e}"
                f"{' (interpret)' if interpret else ''}"))
        payload["configs"][cname] = entry

    JSON_PAYLOAD.clear()
    JSON_PAYLOAD.update(payload)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
