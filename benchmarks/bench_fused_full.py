"""Fused-path trajectory benchmark: per-path wall-clock + modeled HBM bytes.

Measures every registered forward path (:mod:`repro.core.paths`) on the
paper's 30p / 50p configs and pairs each wall-clock with the TPUModel's
modeled HBM traffic at the path's declared fusion level and weight
precision — both read off the :class:`~repro.core.paths.PathSpec`, so a
newly registered path (e.g. the int8 quantized one) lands in this
benchmark, the emitted ``BENCH_fused.json`` and the CI regression gate
with zero edits here.  Each path's numerical error against its own
spec-declared reference fn rides along in the payload so the JSON
records correctness next to speed.

Whole-network ("full") Pallas paths additionally record their autotuned
``(block_b, block_s)`` against the UNTILED model's ``block_b`` at the
modeled batch: the sender-tiled kernel's live set shrinks ~N_o/block_s,
so the batch tile — and with it weight-traffic amortization — grows by
the ratio (``block_b_gain`` in the payload is the cross-PR acceptance
number for the tiling rework).

A large-graph entry (``tracks128``: N_o=128 track-level events,
``configs/jedi_tracks_128``) proves the tiled kernel serves graphs the
untiled working-set model REJECTS (even block_b=1 exceeds the VMEM
budget — ``untiled_rejected`` in the payload); it runs the fp32
``fused_full`` path as ``fp32_fused_full_large``, interpret-mode on CPU.

Pallas paths run in interpret mode off-TPU: their wall-clock is a CPU
emulation (flagged ``"interpret": true`` in the JSON) — the HBM model is
the cross-PR comparable number there, exactly as in bench_fusion.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, select_paths, time_fn
from repro.core import codesign, paths
from repro.core import interaction_net as inet
from repro.data.jets import make_tracks
from repro.kernels.fused_jedinet import autotune as fj_autotune
from repro.kernels.jedi_linear import autotune as jl_autotune

# filled by run(); benchmarks/run.py serializes it to BENCH_fused.json
JSON_PAYLOAD: dict = {}


def _measure(spec, params, cfg, x, interpret: bool):
    if spec.pallas:
        call = jax.jit(lambda p, x_: spec.forward(p, cfg, x_,
                                                  interpret=interpret))
    else:
        call = jax.jit(lambda p, x_: spec.forward(p, cfg, x_))
    iters = 3 if interpret else 10
    return time_fn(call, params, x, warmup=1, iters=iters)


def _entry(spec, us, batch, interpret, hbm, model_batch, err):
    """One payload entry, shared by the per-config loop and tracks128 so
    the schema the regression gate parses cannot diverge between them."""
    return {
        "wall_us": us,
        "batch": batch,
        "interpret": interpret,
        "fused_level": spec.fused_level,
        "quantized": spec.quantized,
        "modeled_hbm_bytes": hbm,
        "modeled_hbm_batch": model_batch,
        "max_abs_err_vs_ref": err,
        "ref_tolerance": spec.tolerance,
    }


def _widths(params):
    return (fj_autotune.mlp_widths(params["fr"]),
            fj_autotune.mlp_widths(params["fo"]),
            fj_autotune.mlp_widths(params["phi"]))


def _linear_tiling(cfg, params, batch: int) -> dict:
    """Batch tile + per-sample live set under the LINEAR model — the
    O(N) kernel has no sender axis, so the grid autotuner's
    (block_b, block_s) numbers do not describe it."""
    fr_w, fo_w, phi_w = _widths(params)
    return {
        "autotuned_block_b": jl_autotune.pick_block_b_linear(
            batch, cfg.n_objects, cfg.n_features, fr_w, fo_w, phi_w,
            reserved_bytes=jl_autotune.weight_vmem_bytes(
                params, cfg.compute_dtype)),
        "linear_per_sample_bytes": jl_autotune.linear_forward_bytes_per_sample(
            cfg.n_objects, cfg.n_features, fr_w, fo_w, phi_w),
    }


def _tiling(cfg, params, batch: int) -> dict:
    """Autotuned tiled (block_b, block_s) vs the untiled model's block_b
    at the same batch — the sender-tiling acceptance numbers.  BOTH
    sides run under the same weight-reserved budget, so block_b_gain
    isolates the tiling effect (not the reservation policy)."""
    fr_w, fo_w, phi_w = _widths(params)
    reserved = fj_autotune.weight_vmem_bytes(params, cfg.compute_dtype)
    budget = fj_autotune.effective_budget(
        fj_autotune.VMEM_BUDGET_BYTES, reserved)
    untiled_per = fj_autotune.full_forward_bytes_per_sample(
        cfg.n_objects, cfg.n_features, fr_w, fo_w, phi_w)
    untiled_fits = fj_autotune.fits_vmem(untiled_per, budget)
    untiled_bb = fj_autotune.pick_block_b(batch, untiled_per, budget)
    bb, bs = fj_autotune.pick_block_b_s(
        batch, cfg.n_objects, cfg.n_features, fr_w, fo_w, phi_w,
        reserved_bytes=reserved)
    return {
        "autotuned_block_b": bb,
        "autotuned_block_s": bs,
        "untiled_block_b": untiled_bb,
        "untiled_per_sample_bytes": untiled_per,
        "untiled_rejected": not untiled_fits,
        "block_b_gain": bb / max(untiled_bb, 1),
    }


def run():
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    payload = {"schema": 1, "backend": jax.default_backend(), "configs": {}}
    names = select_paths()                 # default: the whole registry

    for cname, n_o, batch, ibatch in (("30p", 30, 256, 16),
                                      ("50p", 50, 128, 8)):
        cfg = inet.JediNetConfig(n_objects=n_o, n_features=16)
        params = inet.init(jax.random.PRNGKey(0), cfg, scale="lecun")
        entry = {"n_objects": n_o, "paths": {}}

        for name in names:
            spec = paths.get(name)
            pparams = spec.prepare_params(params)
            interpret = spec.pallas and not on_tpu
            b = ibatch if interpret else batch
            x = jax.random.normal(jax.random.PRNGKey(1), (b, n_o, 16))
            us = _measure(spec, pparams, cfg, x, interpret)
            hbm = codesign.TPUModel.hbm_bytes(
                cfg, batch, 2, spec.fused_level,
                weight_bytes=spec.weight_bytes)
            # path-vs-own-reference error rides along (the spec contract:
            # both fns see the transformed params)
            xq = jax.random.normal(jax.random.PRNGKey(2), (8, n_o, 16))
            fwd = (spec.forward(pparams, cfg, xq, interpret=True)
                   if spec.pallas and not on_tpu
                   else spec.forward(pparams, cfg, xq))
            err = float(jnp.max(jnp.abs(fwd - spec.ref(pparams, cfg, xq))))
            entry["paths"][name] = _entry(spec, us, b, interpret, hbm,
                                          batch, err)
            derived = (f"level={spec.fused_level} "
                       f"modeled_hbm={hbm / 1e6:.2f}MB err={err:.1e}")
            if spec.pallas and spec.fused_level == "full":
                if spec.complexity == "O(N)":
                    tiling = _linear_tiling(cfg, pparams, batch)
                    entry["paths"][name].update(tiling)
                    derived += (f" block_b={tiling['autotuned_block_b']} "
                                "(linear live set, no sender axis)")
                else:
                    tiling = _tiling(cfg, pparams, batch)
                    entry["paths"][name].update(tiling)
                    derived += (f" block_b={tiling['autotuned_block_b']}"
                                f"(x{tiling['block_b_gain']:.1f} vs untiled "
                                f"{tiling['untiled_block_b']})"
                                f" block_s={tiling['autotuned_block_s']}")
            rows.append(row(
                f"fused_paths_{cname}_{name}", us,
                derived + (" (interpret)" if interpret else "")))
        payload["configs"][cname] = entry

    # --- large-graph regime: N_o=128 track-level events ------------------
    # The untiled whole-network kernel cannot hold even ONE sample's
    # (N_o, N_o, H1) grid in the VMEM budget here; the sender-tiled
    # kernel runs it (interpret-mode emulation off-TPU, tiny batch).
    from repro.configs.jedi_tracks_128 import MODEL as large_cfg
    lparams = inet.init(jax.random.PRNGKey(0), large_cfg, scale="lecun")
    lbatch = 512 if on_tpu else 4       # measured batch (interpret is slow)
    model_batch = 512                   # modeled numbers stay backend-
    spec = paths.get("fused_full")      # independent, like 30p/50p above
    tiling = _tiling(large_cfg, lparams, model_batch)
    assert tiling["untiled_rejected"], (
        "tracks128 must exceed the untiled VMEM model "
        f"({tiling['untiled_per_sample_bytes']} B/sample) — "
        "it exists to prove the tiled kernel opens this regime")
    # standardized track-level events (the workload this config models);
    # raw unit-normal inputs would inflate the 127-way sender sums past
    # trained-logit scale and the abs-err column would measure noise
    x = jnp.asarray(make_tracks(np.random.RandomState(1), lbatch,
                                large_cfg.n_objects,
                                large_cfg.n_features)[0])
    us = _measure(spec, lparams, large_cfg, x, not on_tpu)
    xq = x[:2]
    fwd = spec.forward(lparams, large_cfg, xq, interpret=not on_tpu)
    err = float(jnp.max(jnp.abs(fwd - spec.ref(lparams, large_cfg, xq))))
    hbm = codesign.TPUModel.hbm_bytes(large_cfg, model_batch, 2, "full")
    payload["configs"]["tracks128"] = {
        "n_objects": large_cfg.n_objects,
        "paths": {"fp32_fused_full_large": {
            **_entry(spec, us, lbatch, not on_tpu, hbm, model_batch, err),
            **tiling,
        }},
    }
    rows.append(row(
        "fp32_fused_full_large", us,
        f"N_o={large_cfg.n_objects} untiled_rejected="
        f"{tiling['untiled_rejected']} block_b={tiling['autotuned_block_b']} "
        f"block_s={tiling['autotuned_block_s']} err={err:.1e}"
        + ("" if on_tpu else " (interpret)")))

    # head-to-head: the O(N) JEDI-linear kernel in the SAME regime.  128
    # tracks is deep into its scaling win (the f_R grid the fused_full
    # kernel tiles over simply does not exist), so this pair of entries
    # is the measured N_o-scaling crossover record for EXPERIMENTS.md
    # §JEDI-linear.  Different model — its own ref/err, not comparable
    # accuracy-wise, explicitly comparable wall-clock-wise.
    jspec = paths.get("jedi_linear_full")
    jus = _measure(jspec, lparams, large_cfg, x, not on_tpu)
    jfwd = jspec.forward(lparams, large_cfg, xq, interpret=not on_tpu)
    jerr = float(jnp.max(jnp.abs(jfwd - jspec.ref(lparams, large_cfg, xq))))
    jhbm = jspec.roofline_for(large_cfg, [model_batch])[model_batch][
        "hbm_bytes"]
    jtiling = _linear_tiling(large_cfg, lparams, model_batch)
    payload["configs"]["tracks128"]["paths"]["jedi_linear_full_large"] = {
        **_entry(jspec, jus, lbatch, not on_tpu, jhbm, model_batch, jerr),
        **jtiling,
        "speedup_vs_fused_full": us / jus,
    }
    rows.append(row(
        "jedi_linear_full_large", jus,
        f"N_o={large_cfg.n_objects} O(N) "
        f"block_b={jtiling['autotuned_block_b']} err={jerr:.1e} "
        f"speedup_vs_fused_full={us / jus:.1f}x"
        + ("" if on_tpu else " (interpret)")))

    JSON_PAYLOAD.clear()
    JSON_PAYLOAD.update(payload)
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
