"""Paper Table 2 + Sec 5.4.5: eq. (2) latency-model fidelity.

The paper's <5% prediction-error claim applies to the fused architecture
(J3/J4/J5/U4/U5 — "the estimated latency of design J4, J5, U4 and U5 ...
less than 5% prediction errors"); for the unfused J1/J2/U1-U3 (prior-work
architecture) only the II model applies.  We report both.
"""

from __future__ import annotations

from repro.core import codesign
from benchmarks.common import row

FUSED = {"J3", "J4", "J5", "U4", "U5"}


def run():
    rows = []
    worst_fused = 0.0
    for pt in codesign.paper_table2_points():
        m = codesign.FPGAModel.latency_cycles(
            codesign.FPGADesignPoint(cfg=pt["cfg"], n_fr=pt["n_fr"],
                                     r_fo=pt["r_fo"]))
        ii_err = abs(m["ii_cycles"] - pt["paper_ii_cycles"]) \
            / pt["paper_ii_cycles"]
        lat_err = abs(m["latency_cycles"] - pt["paper_latency_cycles"]) \
            / pt["paper_latency_cycles"]
        tag = "fused" if pt["name"] in FUSED else "unfused(prior-work J2-arch)"
        if pt["name"] in FUSED:
            worst_fused = max(worst_fused, lat_err)
        rows.append(row(
            f"table2_{pt['name']}", m["latency_us"] ,
            f"{tag}; II model {m['ii_cycles']} vs paper "
            f"{pt['paper_ii_cycles']} ({ii_err*100:.1f}%); latency model "
            f"{m['latency_cycles']:.0f} vs paper "
            f"{pt['paper_latency_cycles']} ({lat_err*100:.1f}%)"))
    rows.append(row("table2_fused_worst_latency_err", 0.0,
                    f"{worst_fused*100:.2f}% (paper claim: <5%)"))
    assert worst_fused < 0.05, "latency-model fidelity regression"
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
