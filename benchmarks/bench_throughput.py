"""Paper Table 3: CPU/GPU/FPGA platform comparison, extended with (a) this
container's measured CPU throughput through our implementation and (b) the
TPU-v5e roofline projection from the codesign TPUModel.

KGPS = kilo graph-events (jets) per second at batch 1000 (paper's batch).
"""

from __future__ import annotations

import jax

from repro.core import codesign, interaction_net as inet
from benchmarks.common import row, time_fn

# Table 3 reference rows (paper)
PAPER = [
    ("xeon6154_50p", 1.69), ("xeon6154_30p", 17.6),
    ("rtx2080ti_50p", 59.52), ("rtx2080ti_30p", 263.2),
    ("fpga_u250_50p", 1333.0), ("fpga_u250_30p", 1333.0),
]


def run():
    rows = [row(f"table3_paper_{n}", 0.0, f"{k} KGPS (paper)")
            for n, k in PAPER]
    for name, n_o in (("30p", 30), ("50p", 50)):
        cfg = inet.JediNetConfig(n_objects=n_o, n_features=16)
        params = inet.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1000, n_o, 16))
        f = jax.jit(lambda p, x_: inet.forward_sr(p, cfg, x_))
        us = time_fn(f, params, x)
        kgps = 1000 / (us / 1e6) / 1e3
        rows.append(row(f"table3_thiscpu_{name}", us,
                        f"{kgps:.1f} KGPS measured (this container, SR "
                        "path, batch=1000)"))
        # TPU roofline projection (single v5e chip, fused):
        # 1000 jets per step of step_us microseconds.
        tpu = codesign.TPUModel.evaluate(
            codesign.TPUDesignPoint(cfg=cfg, batch=1000), "edge")
        kgps_tpu = 1000 / (tpu["step_us"] * 1e-6) / 1e3
        rows.append(row(f"table3_tpu_roofline_{name}", tpu["step_us"],
                        f"{kgps_tpu:.0f} KGPS roofline-projected "
                        f"(1x v5e chip, {tpu['bound']}-bound; paper FPGA: "
                        "1333 KGPS)"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
