"""Roofline summary: aggregates the dry-run JSON records into the
per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline.

Also computes MODEL_FLOPS / HLO_FLOPs (useful-compute ratio) for the LM
train cells.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def model_flops_for(arch_id: str, shape_name: str, kind: str):
    """Analytic MODEL_FLOPS for LM cells: 6*N_active*D (train)."""
    from repro.configs.registry import get_arch
    from repro.launch.roofline import model_flops_lm
    arch = get_arch(arch_id)
    if arch.family != "lm":
        return None
    shape = arch.shapes[shape_name]
    if kind == "train":
        n_tok = shape.dim("global_batch") * shape.dim("seq_len")
        return model_flops_lm(arch.model, n_tok, train=True)
    if shape.kind == "prefill":
        n_tok = shape.dim("global_batch") * shape.dim("seq_len")
        return model_flops_lm(arch.model, n_tok, train=False)
    if shape.kind == "decode":
        return model_flops_lm(arch.model, shape.dim("global_batch"),
                              train=False)
    return None


def load_records():
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*", "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run():
    rows = []
    recs = load_records()
    n_ok = sum(r["status"] == "ok" for r in recs)
    rows.append(row("dryrun_cells", 0.0,
                    f"{n_ok}/{len(recs)} (arch x shape x mesh) compiled"))
    for r in recs:
        if r["status"] != "ok":
            rows.append(row(f"roofline_{r['mesh']}_{r['arch']}__"
                            f"{r['shape']}", 0.0, f"FAILED {r['error']}"))
            continue
        rf = r["roofline"]
        mf = model_flops_for(r["arch"], r["shape"], r.get("kind", ""))
        useful = ""
        if mf and rf["flops_per_chip"]:
            ratio = (mf / rf["chips"]) / rf["flops_per_chip"]
            useful = f"; useful-compute {ratio:.2f}"
        rows.append(row(
            f"roofline_{r['mesh']}_{r['arch']}__{r['shape']}",
            rf["step_s"] * 1e6,
            f"bound={rf['bound']} c={rf['compute_s']*1e3:.2f}ms "
            f"m={rf['memory_s']*1e3:.2f}ms x={rf['collective_s']*1e3:.2f}ms"
            f"{useful}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
