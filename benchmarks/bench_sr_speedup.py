"""Paper Fig 9: latency reduction from strength-reduced MMMs.

Measured on this container's CPU backend (the *relative* effect of
removing the dense adjacency MMMs is hardware-independent; absolute TPU
numbers come from the roofline in EXPERIMENTS.md)."""

from __future__ import annotations

import jax

from repro.core import interaction_net as inet
from benchmarks.common import row, time_fn


def run():
    rows = []
    for name, n_o in (("30p", 30), ("50p", 50)):
        cfg = inet.JediNetConfig(n_objects=n_o, n_features=16)
        params = inet.init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (256, n_o, 16))
        dense = jax.jit(lambda p, x_: inet.forward_dense(p, cfg, x_))
        sr = jax.jit(lambda p, x_: inet.forward_sr(p, cfg, x_))
        t_dense = time_fn(dense, params, x)
        t_sr = time_fn(sr, params, x)
        rows.append(row(f"fig9_dense_{name}", t_dense, "batch=256"))
        rows.append(row(f"fig9_sr_{name}", t_sr,
                        f"speedup {t_dense / t_sr:.2f}x over dense MMMs"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
