"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax
import numpy as np

# Forward-path selection for path-parametrized benchmarks, set by
# ``benchmarks.run --paths``: None = each module's default subset,
# ["all"] = the whole registry, anything else = explicit names.
PATH_FILTER: list[str] | None = None


def select_paths(default=None) -> list[str]:
    """Resolve the benchmark's path list against the registry.

    ``default`` is the module's own subset (None = whole registry);
    the ``--paths`` CLI filter overrides it.  Names are validated
    through ``paths.get`` so a typo fails loudly, not by measuring
    nothing.
    """
    from repro.core import paths
    if PATH_FILTER is None:
        names = list(default) if default is not None else paths.available()
    elif PATH_FILTER == ["all"]:
        names = paths.available()
    else:
        names = list(PATH_FILTER)
    for n in names:
        paths.get(n)
    return names


def calibration_us(iters: int = 12) -> float:
    """Median wall time of a fixed jitted XLA workload (microseconds).

    A machine-speed yardstick stamped into every BENCH_*.json payload:
    the regression gate divides fresh wall-clocks by the fresh/baseline
    calibration ratio, normalizing away global runner-speed differences
    (CI hardware generations, CPU throttling) while per-path regressions
    — which move relative to the yardstick — still trip the gate.  The
    workload is a jitted matmul so the yardstick exercises the same XLA
    runtime/threadpool the benchmarks do, not just BLAS.
    """
    import jax.numpy as jnp
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.normal(0, 1, (512, 512)).astype(np.float32))
    fn = jax.jit(lambda x: x @ x + x)
    return time_fn(fn, a, warmup=3, iters=iters)


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (blocks on async)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> dict:
    return {"name": name, "us_per_call": us, "derived": derived}


def print_rows(rows):
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.2f},{r['derived']}")
