"""CI perf-regression gate over the committed BENCH_*.json baselines.

    python benchmarks/check_regression.py --fresh-dir bench_out

Compares freshly produced ``BENCH_fused.json`` / ``BENCH_serving.json``
against the committed baselines (repo root by default) and exits 1 when
any path's wall-clock regresses by more than ``--max-regress`` (default
1.30 = +30%, sized for CPU-CI noise).  What is compared:

* ``BENCH_fused.json`` — per (config, path) ``wall_us``;
* ``BENCH_serving.json`` — per (config, path, bucket)
  ``per_event_min_us`` (the serving tier's wall-clock-per-event; min is
  the noise-robust estimator, falling back to ``per_event_p50_us`` for
  baselines that predate it).

Wall-clocks are normalized by the fresh/baseline ``calibration_us``
ratio when both payloads carry one (a fixed numpy workload timed at
emission): a slower CI runner or a throttled laptop shifts every number
AND the yardstick, so the gate only fires on paths that regress
*relative to the machine*.  Entries are only compared when they are
comparable: same backend, same interpret flag, both present.
Interpret-mode entries (Pallas kernels emulated off-TPU — "trends, not
truth" per EXPERIMENTS.md) get ``--interpret-slack`` (default 2x) on
top of the threshold: their pure-Python wall-clocks track neither BLAS
nor XLA yardsticks.  New paths/buckets (no baseline yet) and removed
ones are reported but never fail the gate — growth is not a
regression, but unseeded entries are named explicitly (with the exact
bootstrap command) so they cannot linger ungated.  Passing
``--bootstrap`` (env ``BENCH_BOOTSTRAP=1``) goes one further: entries
a fresh run has but the committed baseline lacks — e.g. a path newly
registered in the forward-path registry — are merged INTO the baseline
file, speed-normalized to the baseline machine's calibration, so the
very next run gates them; commit the updated BENCH_*.json in the same
PR that adds the path.  A baseline FILE missing entirely (or
unparseable) is a gate FAILURE with the bootstrap recipe printed,
naming the fresh paths that need seeding — a silently green gate would
hide real regressions forever.  KGPS drops are reported as warnings
only (KGPS is the inverse of a wall-clock already gated).

Introducing a path (or several at once, e.g. the jedi_linear family)
touches BOTH files in ONE pass: produce the fresh payloads in a single
quiet window (`PYTHONPATH=src python -m benchmarks.run --only
fused_paths,serving --out-dir bench_out` — serialized, nothing else
running, so the shared calibration stamp is honest for every new
entry), then `python benchmarks/check_regression.py --fresh-dir
bench_out --bootstrap` seeds the new entries into BENCH_fused.json AND
BENCH_serving.json together and the next run gates them.  Never seed
the two files from different windows: their calibrations would
disagree about machine speed and the first gated run would see a
phantom regression on one of them.

Intentional baseline refresh: regenerate the committed files with

    PYTHONPATH=src python -m benchmarks.run --only fused_paths,serving

(writes to the repo root) and commit them, or set the override knob
``BENCH_REGRESS_OK=1`` (env) / ``--allow-regress`` to turn failures
into warnings for one run.  Documented in EXPERIMENTS.md §Serving.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

PAIRS = ("BENCH_fused.json", "BENCH_serving.json")


def _load(path):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        try:
            return json.load(f)
        except json.JSONDecodeError as e:
            # a clear verdict beats a raw traceback: the gate treats a
            # corrupt payload like a missing one, with the remedy named
            print(f"  WARN: {path} is not valid JSON ({e}); "
                  "treating as missing — regenerate it with "
                  "`PYTHONPATH=src python -m benchmarks.run "
                  "--only fused_paths,serving`")
            return None


def _comparable(fresh, base):
    return fresh.get("backend") == base.get("backend")


def _iter_fused(doc):
    """Yields (key, entry) per (config, path)."""
    for cname, c in doc.get("configs", {}).items():
        for pname, p in c.get("paths", {}).items():
            yield f"{cname}/{pname}", p


def _iter_serving(doc):
    """Yields (key, entry ('interpret' folded in)) per (config, path, bucket)."""
    for cname, c in doc.get("configs", {}).items():
        for pname, p in c.get("paths", {}).items():
            for bname, b in p.get("buckets", {}).items():
                yield f"{cname}/{pname}/b{bname}", dict(
                    b, interpret=p.get("interpret"))


def _speed_scale(fresh, base) -> float:
    """fresh/baseline machine-speed ratio from the calibration stamps
    (1.0 when either payload predates calibration)."""
    fc, bc = fresh.get("calibration_us"), base.get("calibration_us")
    if fc and bc and bc > 0:
        return fc / bc
    return 1.0


def _scale_times(node, scale):
    """Deep-copy ``node`` with measured wall-clocks normalized from
    fresh-machine to baseline-machine units (divide ``*_us`` by the
    speed scale, multiply ``kgps``).  ``modeled_*`` fields are analytic
    — machine-independent — and pass through untouched."""
    out = {}
    for k, v in node.items():
        if isinstance(v, dict):
            out[k] = _scale_times(v, scale)
        elif (isinstance(v, (int, float)) and not isinstance(v, bool)
              and k.endswith("_us") and not k.startswith("modeled_")):
            out[k] = v / scale
        elif k == "kgps" and isinstance(v, (int, float)):
            out[k] = v * scale
        else:
            out[k] = v
    return out


def bootstrap_new_entries(fresh, base, scale) -> list:
    """Merge configs/paths/buckets present in ``fresh`` but missing from
    ``base`` (in place), speed-normalized; returns the added keys.

    This is how a newly registered forward path gets its first committed
    baseline: the gate seeds the entry instead of flagging it forever.
    Existing entries are never touched — a regression still regresses.
    """
    added = []
    for cname, c in fresh.get("configs", {}).items():
        bconfigs = base.setdefault("configs", {})
        if cname not in bconfigs:
            bconfigs[cname] = {k: v for k, v in c.items() if k != "paths"}
            bconfigs[cname]["paths"] = {}
        bpaths = bconfigs[cname].setdefault("paths", {})
        for pname, p in c.get("paths", {}).items():
            if pname not in bpaths:
                bpaths[pname] = _scale_times(p, scale)
                added.append(f"{cname}/{pname}")
            elif "buckets" in p:
                bbuckets = bpaths[pname].setdefault("buckets", {})
                for bname, b in p["buckets"].items():
                    if bname not in bbuckets:
                        bbuckets[bname] = _scale_times(b, scale)
                        added.append(f"{cname}/{pname}/b{bname}")
    return added


def compare(fresh, base, iterate, metrics, max_regress, *, scale=1.0,
            interpret_slack=1.0, warn_metric=None,
            warn_higher_is_better=False):
    """Returns (failures, warnings, infos, new_keys) line lists.

    ``metrics`` is a preference list; the first key present in BOTH
    entries is gated.  Fresh values are divided by ``scale`` (the
    machine-speed ratio) before comparing.  ``new_keys`` are entries
    the fresh run has but the baseline lacks — the caller prints the
    bootstrap recipe naming them so a new path never lingers ungated.
    """
    failures, warnings, infos, new_keys = [], [], [], []
    fresh_e = dict(iterate(fresh))
    base_e = dict(iterate(base))
    for key in sorted(set(fresh_e) | set(base_e)):
        f, b = fresh_e.get(key), base_e.get(key)
        if f is None:
            infos.append(f"{key}: dropped (no fresh entry)")
            continue
        if b is None:
            infos.append(f"{key}: new (no baseline; --bootstrap seeds it) "
                         f"{metrics[0]}={f.get(metrics[0], float('nan')):.2f}")
            new_keys.append(key)
            continue
        if f.get("interpret") != b.get("interpret"):
            infos.append(f"{key}: interpret flag changed — not compared")
            continue
        metric = next((m for m in metrics if f.get(m) and b.get(m)), None)
        if metric is None:
            infos.append(f"{key}: no shared metric of {metrics} — skipped")
            continue
        fv, bv = f[metric] / scale, b[metric]
        ratio = fv / bv
        limit = max_regress * (interpret_slack if f.get("interpret") else 1.0)
        line = (f"{key}: {metric} {bv:.2f} -> {fv:.2f} us "
                f"({ratio:.0%} of baseline, speed-normalized, "
                f"limit {limit:.0%})")
        if ratio > limit:
            failures.append(line)
        else:
            infos.append(line)
        if warn_metric and b.get(warn_metric) and f.get(warn_metric):
            # throughput scales inversely with machine speed
            norm = scale if warn_higher_is_better else 1.0 / scale
            wr = f[warn_metric] * norm / b[warn_metric]
            bad = wr < 1 / max_regress if warn_higher_is_better \
                else wr > max_regress
            if bad:
                warnings.append(
                    f"{key}: {warn_metric} {b[warn_metric]:.2f} -> "
                    f"{f[warn_metric]:.2f}")
    return failures, warnings, infos, new_keys


def _failing_path_names(failure_lines) -> set:
    """Registered-path names out of failure lines shaped
    ``BENCH_x.json: cfg/path[/bucket]: metric ...``."""
    names = set()
    for line in failure_lines:
        _, _, rest = line.partition(": ")
        key = rest.split(":", 1)[0]
        parts = key.split("/")
        if len(parts) >= 2:
            names.add(parts[1])
    return names


def _audit_hint(failure_lines) -> None:
    """Best-effort cross-reference with the static kernel-contract
    auditor: a regressing path whose VMEM/dtype contract ALSO fails
    statically points at kernel/bytes-model drift, not machine noise —
    print the audit command naming it.  Never breaks the gate."""
    try:
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        if src not in sys.path:
            sys.path.insert(0, src)
        import jax

        from repro.analysis.kernel_audit import audit_registry
        from repro.configs.jedi_30p import MODEL as cfg
        from repro.core import interaction_net
        from repro.core import paths as registry
        names = sorted(_failing_path_names(failure_lines)
                       & set(registry.available()))
        if not names:
            return
        params = interaction_net.init(jax.random.PRNGKey(0), cfg)
        findings = audit_registry(cfg, params, names=names)
    except Exception as e:  # the gate's verdict must not depend on this
        print(f"(kernel-contract cross-check unavailable: {e})")
        return
    flagged = sorted({f.location.split()[0].removeprefix("path=")
                      for f in findings if f.location.startswith("path=")})
    if flagged:
        print("NOTE: the kernel-contract auditor ALSO flags "
              f"{', '.join(flagged)} — this regression likely tracks "
              "kernel/VMEM-model drift, not machine noise.  Details:\n"
              "    PYTHONPATH=src python -m repro.analysis --audit-only "
              f"--paths {','.join(flagged)}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default="bench_out",
                    help="directory holding freshly produced BENCH_*.json")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed baselines")
    ap.add_argument("--max-regress", type=float, default=1.30,
                    help="fail when fresh/baseline wall-clock exceeds this")
    ap.add_argument("--interpret-slack", type=float, default=2.0,
                    help="extra factor on the threshold for interpret-mode "
                         "(off-TPU Pallas emulation) entries")
    ap.add_argument("--allow-regress", action="store_true",
                    help="report regressions but exit 0 (baseline refresh)")
    ap.add_argument("--bootstrap", action="store_true",
                    help="seed baseline entries for fresh paths/buckets "
                         "that have none yet (write the baseline file)")
    args = ap.parse_args(argv)
    allow = args.allow_regress or os.environ.get("BENCH_REGRESS_OK") == "1"
    bootstrap = args.bootstrap or os.environ.get("BENCH_BOOTSTRAP") == "1"

    all_failures = []
    for name in PAIRS:
        base_path = os.path.join(args.baseline_dir, name)
        fresh = _load(os.path.join(args.fresh_dir, name))
        base = _load(base_path)
        print(f"== {name} ==")
        if fresh is None:
            print(f"  FAIL: no fresh file in {args.fresh_dir}")
            all_failures.append(f"{name}: missing fresh file")
            continue
        if base is None:
            if bootstrap:
                os.makedirs(os.path.dirname(base_path) or ".", exist_ok=True)
                with open(base_path, "w") as f:
                    json.dump(fresh, f, indent=2, sort_keys=True)
                print(f"  no committed baseline — bootstrapped {base_path} "
                      "from the fresh run; commit it")
            else:
                # a silently green gate on a missing baseline hides real
                # regressions forever — fail with the bootstrap recipe,
                # naming the fresh entries that need their first baseline
                iterate = _iter_fused if name == "BENCH_fused.json" \
                    else _iter_serving
                fresh_keys = sorted(k for k, _ in iterate(fresh))
                listing = ", ".join(fresh_keys) if fresh_keys \
                    else "(fresh file has no entries)"
                print(f"  FAIL: no committed baseline at {base_path}.\n"
                      f"  Unseeded entries: {listing}\n"
                      "  Bootstrap them from this fresh run with\n"
                      "      python benchmarks/check_regression.py "
                      f"--fresh-dir {args.fresh_dir} --bootstrap\n"
                      "  (or BENCH_BOOTSTRAP=1) and commit the written "
                      "file.")
                all_failures.append(
                    f"{name}: missing baseline (seed it with --bootstrap)")
            continue
        if not _comparable(fresh, base):
            print(f"  backends differ (fresh={fresh.get('backend')} "
                  f"baseline={base.get('backend')}) — not comparable, skipped")
            continue
        scale = _speed_scale(fresh, base)
        print(f"  machine-speed scale: {scale:.2f}x "
              f"(fresh/baseline calibration)")
        if scale > 1.5 or scale < 1 / 1.5:
            fc, bc = fresh.get("calibration_us"), base.get("calibration_us")
            print(
                "  " + "!" * 66 + "\n"
                f"  WARN: calibration stamps differ by {scale:.2f}x — fresh "
                f"{fc:.1f} us vs baseline {bc:.1f} us.\n"
                "  The fresh run and the committed baseline were measured "
                "on machines\n"
                "  (or machine states) of very different speed; the "
                "speed-normalized\n"
                "  verdicts below lean entirely on the calibration "
                "yardstick.  This\n"
                "  container's CPU drifts ~2x between windows — REGENERATE "
                "BASELINE AND\n"
                "  COMPARISON IN THE SAME QUIET WINDOW before trusting a "
                "failure here\n"
                "  (serialized run, nothing else on the machine; see "
                "EXPERIMENTS.md\n"
                "  §Serving).\n"
                "  " + "!" * 66)
        if name == "BENCH_fused.json":
            fails, warns, infos, new = compare(
                fresh, base, _iter_fused, ["wall_us"], args.max_regress,
                scale=scale, interpret_slack=args.interpret_slack)
        else:
            fails, warns, infos, new = compare(
                fresh, base, _iter_serving,
                ["per_event_min_us", "per_event_p50_us"], args.max_regress,
                scale=scale, interpret_slack=args.interpret_slack,
                warn_metric="kgps", warn_higher_is_better=True)
        for line in infos:
            print(f"  {line}")
        for line in warns:
            print(f"  WARN: {line}")
        for line in fails:
            print(f"  REGRESSION: {line}")
        all_failures.extend(f"{name}: {line}" for line in fails)
        if bootstrap:
            added = bootstrap_new_entries(fresh, base, scale)
            if added:
                with open(base_path, "w") as f:
                    json.dump(base, f, indent=2, sort_keys=True)
                print(f"  bootstrapped {len(added)} baseline entr"
                      f"{'y' if len(added) == 1 else 'ies'} into "
                      f"{base_path} (speed-normalized): "
                      f"{', '.join(added)} — commit this file")
        elif new:
            # name the unseeded entries + the exact command: a newly
            # introduced path must not linger ungated behind an info line
            print(f"  NOTE: {len(new)} entr{'y' if len(new) == 1 else 'ies'} "
                  f"without a committed baseline: {', '.join(new)}\n"
                  "  Seed them (fresh files from ONE quiet window) with\n"
                  "      python benchmarks/check_regression.py "
                  f"--fresh-dir {args.fresh_dir} --bootstrap\n"
                  "  and commit the updated baseline file(s).")

    if all_failures:
        print(f"\n{len(all_failures)} perf regression(s) "
              f"(> {args.max_regress:.0%} of baseline):")
        for line in all_failures:
            print(f"  {line}")
        _audit_hint(all_failures)
        if allow:
            print("override active (BENCH_REGRESS_OK=1 / --allow-regress): "
                  "exiting 0; refresh the committed baselines in this PR")
            return 0
        print("intentional? refresh baselines with "
              "`PYTHONPATH=src python -m benchmarks.run "
              "--only fused_paths,serving` and commit, or set "
              "BENCH_REGRESS_OK=1 for this run")
        return 1
    print("\nno perf regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
