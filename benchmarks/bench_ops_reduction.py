"""Paper Fig 8: multiplication/addition/iteration reduction from the
strength-reduced MMMs, for JEDI-net-30p and -50p."""

from __future__ import annotations

from repro.core.adjacency import mmm_op_counts
from benchmarks.common import row


def run():
    rows = []
    for name, n_o in (("30p", 30), ("50p", 50)):
        c = mmm_op_counts(n_o, 16, 8)
        rows.append(row(
            f"fig8_mmm12_{name}", 0.0,
            f"mults {c['mmm12_baseline_mults']}->{c['mmm12_sr_mults']}; "
            f"adds {c['mmm12_baseline_adds']}->{c['mmm12_sr_adds']}"))
        frac = c["mmm3_sr_adds"] / c["mmm3_baseline_adds"]
        rows.append(row(
            f"fig8_mmm3_{name}", 0.0,
            f"mults {c['mmm3_baseline_mults']}->0; adds "
            f"{c['mmm3_baseline_adds']}->{c['mmm3_sr_adds']} "
            f"({frac * 100:.1f}% remain; paper 30p: 6960 = 3.3%)"))
        it = c["iterations_sr"] / c["iterations_baseline"]
        rows.append(row(
            f"fig8_iters_{name}", 0.0,
            f"iterations {c['iterations_baseline']}->{c['iterations_sr']} "
            f"({(1 - it) * 100:.1f}% reduction; paper: 96.7%/98%)"))
    # verify the 30p headline numbers exactly
    c = mmm_op_counts(30, 16, 8)
    assert c["mmm3_sr_adds"] == 6960
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
